"""Whole-program megakernel — one Pallas launch per sample for a whole plan.

MAFIA's claim is that the *whole program* — not per-op calls — compiles into
one tightly-scheduled accelerator program (paper §IV-G).  The per-chain
pipeline kernel (:mod:`repro.kernels.linear_pipeline`) removed per-node HBM
round-trips inside a cluster; this module removes the remaining inter-step
dispatch: the lowering pipeline's linearize pass compiles the executable
portion of an :class:`~repro.core.lowering.ExecutionPlan` down to a flat,
statically-scheduled instruction stream over a tiny VLIW-ish ISA, and
:func:`run_segment` executes the whole stream in **one** ``pallas_call``.

ISA (all operands static — shapes, shifts and constants are resolved at
compile time by ``_pass_linearize``):

    ==============  ==========================================================
    ``LOAD_VEC``    ``reg[dst] ← consts[ci]`` or ``reg[dst] ← inputs[ii]``
    ``LOAD_MAT``    start the async HBM→VMEM copy of ``matrices[mi]`` into
                    its dedicated VMEM buffer (DMA + semaphore)
    ``MATVEC``      wait the DMA, then ``reg[dst] ← W @ reg[src0]`` (+ static
                    bias) — dense gemv on the VMEM-resident tile
    ``SPMV``        same compute on a sparse (dense-with-zeros) operand —
                    kept as a distinct opcode mirroring the paper's separate
                    SpMV template (nnz metadata rides the operand)
    ``ELEMENTWISE`` one fused-pipeline stage (float or ``q_*`` vocabulary of
                    :mod:`repro.kernels.ref`) on ``reg[src0]`` (and
                    ``reg[src1]`` for ``*_arr`` forms)
    ``REQUANTIZE``  int lanes: requantizing shift of the int32 accumulator
                    after a MATVEC/SPMV (per-tensor shift, or per-row shifts
                    for per-channel scales)
    ``ARGMAX``      ``reg[dst] ← argmax(reg[src0])`` — the index as a width-1
                    value.  On the int lanes this runs directly on the int32
                    carrier: the dequantize scale is a positive power of two
                    (strictly monotone), so carrier argmax is bitwise the
                    dequantized argmax, ties included
    ``REDUCE``      ``reg[dst] ← sum/max/min(reg[src0])`` (width 1).  Int
                    lanes mirror the per-node dequantize → float reduce →
                    requantize fallback exactly (operand carries the
                    calibrated exponents)
    ``SQL2``        squared-L2 distances of ``reg[src0]`` to each column of a
                    matrix-pool operand (ProtoNN's RBF distance kernel) —
                    matvec-like: the points matrix rides the double-buffered
                    DMA schedule; int lanes dequantize → float → requantize
    ``DOT``         ``reg[dst] ← reg[src0] · reg[src1]`` (width 1); int lanes
                    dequantize both operands → float dot → requantize
    ``STORE``       ``outputs[oi] ← reg[src0]`` (cast to that output's dtype:
                    the narrow activation dtype on the int lanes, int32 for
                    integer-valued outputs such as ARGMAX indices)
    ==============  ==========================================================

The register file is a set of VMEM scratch rows, one ``(1, n)`` buffer per
slot with the value's *exact* feature length — exact shapes are what keeps
the float32 lane bitwise identical to per-node eval (padding a contraction
changes XLA's reduction grouping).  Slots are allocated by the linearize
pass with liveness-based reuse, so the file is far smaller than the value
count.  Matrix operands stay in HBM (``ANY`` memory space) and are DMA'd
into dedicated VMEM buffers; the instruction stream issues each ``LOAD_MAT``
one matvec *ahead* of its use, so at most two copies are in flight and the
k-th copy overlaps the (k−1)-th matvec — double-buffered tiles at
instruction granularity.

Int lanes ride the int32 carrier: inputs widen on ``LOAD_VEC``, every value
in the file is int32 (saturated to the activation width except between a
MATVEC and its REQUANTIZE), and ``STORE`` narrows — bit-identical to
per-node integer eval, like the fused chains.

The pure-jnp twin (:func:`repro.kernels.ref.run_segment_ref`) executes the
same stream without Pallas and is the parity oracle for interpret mode.

**Batch-grid lane** (:func:`run_segment_grid`): the serving path used to
``jax.vmap`` the whole launch over the bucket — one *logical* kernel program
per sample, each with its own HBM→VMEM matrix DMAs.  The grid lane instead
puts the batch axis into the Pallas grid: ``grid=(bucket,)``, per-sample
vector rows indexed by ``pl.program_id(0)`` through the BlockSpec index
maps, and every matrix DMA'd **once** on grid step 0 (TPU grid steps are
sequential, so the VMEM tile persists across samples) — one launch per
bucket per segment, which is exactly one launch for an island-free program.
"""

from __future__ import annotations

import dataclasses
import functools
import weakref
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import apply_stage, apply_stage_q

__all__ = ["Instr", "MegakernelSegment", "MegakernelProgram", "run_segment",
           "run_segment_grid"]

ISA_OPS = ("LOAD_VEC", "LOAD_MAT", "MATVEC", "SPMV", "ELEMENTWISE",
           "REQUANTIZE", "ARGMAX", "REDUCE", "SQL2", "DOT", "STORE")


@dataclasses.dataclass(frozen=True)
class Instr:
    """One megakernel instruction.  ``dst``/``src`` index register slots;
    ``operand`` is the op-specific static payload (see module docstring).
    Array payloads (constants, biases, vec operands, per-row shift tables)
    live in the segment's const *pool* and are referenced by index ``ci`` —
    Pallas kernels cannot close over arrays, so the pool rides as extra
    VMEM inputs of the launch:

    * ``LOAD_VEC`` — ``("const", ci)`` or ``("in", ii)``
    * ``LOAD_MAT`` — ``mi`` (matrix index)
    * ``MATVEC``/``SPMV`` — ``(mi, bias_ci)`` with ``bias_ci`` a pool index
      or None (int lanes: the int32 bias at the accumulator scale)
    * ``ELEMENTWISE`` — ``(stage, vec_cis)``: a stage tuple in the
      :mod:`repro.kernels.ref` vocabulary (``*_arr`` index remapped to 0 →
      ``src[1]``); q-stage ``vi`` operand indices address ``vec_cis``
      positionally, a float ``*_vec`` stage's operand is ``vec_cis[0]``
    * ``REQUANTIZE`` — ``("tensor", shift)`` or ``("rows", shifts_ci)``
    * ``ARGMAX`` — None (the int32 carrier / float32 slot holds the index)
    * ``REDUCE`` — ``(kind, e_in, e_out)`` with ``kind`` in ``sum/max/min``;
      exponents are None on the float lane (no dequantize/requantize)
    * ``SQL2`` — ``(mi, e_in, e_out)``: matrix index of the (d, m) points
      operand plus the int-lane exponents (None on the float lane)
    * ``DOT`` — ``(e_a, e_b, e_out)`` (all None on the float lane)
    * ``STORE`` — ``oi`` (output index)
    """

    op: str
    dst: int = -1
    src: tuple[int, ...] = ()
    operand: Any = None
    nid: str = ""                    # DFG node realized (debug / tracing)


@dataclasses.dataclass(frozen=True)
class MegakernelSegment:
    """A maximal run of ISA-encodable plan steps, compiled to one launch."""

    instrs: tuple[Instr, ...]
    slot_widths: tuple[int, ...]          # exact feature length per register
    consts: tuple[Any, ...]               # array payload pool (extra inputs)
    matrices: tuple[Any, ...]             # MATVEC/SPMV weight operands
    in_refs: tuple[str, ...]              # env refs consumed, LOAD_VEC order
    out_refs: tuple[str, ...]             # env refs produced, STORE order
    out_widths: tuple[int, ...]
    out_shapes: tuple[tuple[int, ...], ...]
    quantized: bool = False
    bits: int = 8
    members: tuple[str, ...] = ()         # DFG nodes realized by this segment
    # per-output dtype names ("float32"/"int8"/.../"int32"): integer-valued
    # outputs (ARGMAX indices) stay int32 while quantized activations narrow.
    # Empty = legacy uniform dtype (activation dtype / float32).
    out_dtypes: tuple[str, ...] = ()


@dataclasses.dataclass(frozen=True)
class MegakernelProgram:
    """The linearized plan: megakernel segments interleaved (plan order) with
    the indices of steps that have no ISA encoding — the interpreted islands
    of the hybrid fallback.  A fully encodable plan has one segment."""

    items: tuple[tuple[str, Any], ...]    # ("seg", segment) | ("step", idx)

    @property
    def segments(self) -> list[MegakernelSegment]:
        return [p for k, p in self.items if k == "seg"]

    @property
    def n_islands(self) -> int:
        return sum(1 for k, _ in self.items if k == "step")

    @property
    def n_instrs(self) -> int:
        return sum(len(s.instrs) for s in self.segments)

    def fingerprint(self) -> str:
        """Content digest of the linearized program: every instruction
        (opcode, slots, static operands) plus the const/matrix pools byte
        for byte.  Two programs with equal fingerprints execute the
        identical single-launch stream — this is what the artifact store
        validates on load: the re-linearized plan must reproduce exactly
        the stream that was serialized, else the artifact was produced by
        a different toolchain and must not silently serve."""
        import hashlib

        h = hashlib.sha256()
        for kind, payload in self.items:
            if kind == "step":
                h.update(repr(("step", payload)).encode())
                continue
            seg = payload
            h.update(repr(("seg", seg.slot_widths, seg.in_refs, seg.out_refs,
                           seg.out_widths, seg.out_shapes, seg.quantized,
                           seg.bits, seg.members, seg.out_dtypes)).encode())
            for ins in seg.instrs:
                h.update(repr((ins.op, ins.dst, ins.src, ins.operand,
                               ins.nid)).encode())
            for pool in (seg.consts, seg.matrices):
                for arr in pool:
                    a = np.asarray(arr)
                    h.update(repr((a.dtype.str, a.shape)).encode())
                    h.update(a.tobytes())
        return h.hexdigest()

    def summary(self) -> str:
        segs = self.segments
        return (f"MegakernelProgram({len(segs)} segments, "
                f"{self.n_instrs} instrs, "
                f"{sum(len(s.slot_widths) for s in segs)} slots, "
                f"{self.n_islands} interpreted islands)")


_VEC_STAGES = ("add_vec", "sub_vec", "hadamard_vec")

_REDUCE_F = {"sum": jnp.sum, "max": jnp.max, "min": jnp.min}


def _seg_out_dtypes(seg: MegakernelSegment) -> list:
    """Effective per-output dtypes: the segment's ``out_dtypes`` when set,
    else the legacy uniform dtype (narrow activation dtype / float32)."""
    if getattr(seg, "out_dtypes", ()):
        return [jnp.dtype(d) for d in seg.out_dtypes]
    if seg.quantized:
        from repro.core.quantize import int_dtype

        return [jnp.dtype(int_dtype(seg.bits))] * len(seg.out_refs)
    return [jnp.dtype(jnp.float32)] * len(seg.out_refs)


def _segment_kernel(*refs, seg: MegakernelSegment, skip_dma: bool = False,
                    grid: bool = False):
    """On-core interpreter: the instruction stream unrolls into straight-line
    code at trace time (every operand is static), exactly like MAFIA's
    generated pipeline — there is no runtime dispatch left to do.

    ``skip_dma`` (interpret mode only): the HBM→VMEM double-buffering is a
    hardware-motivated data movement, not arithmetic — on the CPU emulation
    the "DMA" lowers to real array copies that only add latency.  The
    emulation reads matrix operands in place instead; every arithmetic op
    is identical, so parity with the DMA path is bitwise.

    ``grid`` (batch-grid lane): the launch carries ``grid=(bucket,)`` and
    this body runs once per sample.  Matrix DMAs are predicated on
    ``pl.program_id(0) == 0`` — grid steps execute sequentially on TPU, so
    the VMEM tiles loaded on step 0 serve every later sample: one HBM→VMEM
    copy per matrix per *bucket* instead of per sample."""
    from repro.core.quantize import (dequantize, quantize_core,
                                     requantize_core, requantize_rows)

    n_in, n_const, n_mat = len(seg.in_refs), len(seg.consts), len(seg.matrices)
    n_out, n_slot = len(seg.out_refs), len(seg.slot_widths)
    ins = refs[:n_in]
    crefs = refs[n_in:n_in + n_const]
    mats = refs[n_in + n_const:n_in + n_const + n_mat]
    base = n_in + n_const + n_mat
    outs = refs[base:base + n_out]
    slots = refs[base + n_out:base + n_out + n_slot]
    mbufs = refs[base + n_out + n_slot:base + n_out + n_slot + n_mat]
    sems = refs[base + n_out + n_slot + n_mat:]
    carrier = jnp.int32 if seg.quantized else jnp.float32
    copies: dict[int, Any] = {}          # in-flight DMAs (trace-time only)

    def dma(fn):
        """Issue one DMA start/wait — predicated to grid step 0 on the
        batch-grid lane (the tile persists across sequential grid steps)."""
        if grid:
            pl.when(pl.program_id(0) == 0)(fn)
        else:
            fn()

    def dq(x, e):
        """Dequantize-or-passthrough, exactly the per-node dq fallback."""
        return x if e is None else dequantize(x, e)

    def q(x, e):
        """Quantize-or-passthrough on the int32 carrier (value-identical to
        the per-node ``quantize_jnp`` — STORE narrows on exit)."""
        return x if e is None else quantize_core(x, e, seg.bits)

    for instr in seg.instrs:
        op = instr.op
        if op == "LOAD_VEC":
            kind, idx = instr.operand
            src = ins[idx] if kind == "in" else crefs[idx]
            slots[instr.dst][...] = src[...].astype(carrier)
        elif op == "LOAD_MAT":
            if skip_dma:
                continue
            mi = instr.operand
            cp = pltpu.make_async_copy(mats[mi], mbufs[mi], sems[mi])
            dma(cp.start)
            copies[mi] = cp
        elif op in ("MATVEC", "SPMV"):
            mi, bias_ci = instr.operand
            if not skip_dma:
                dma(copies.pop(mi).wait)
            tile = mats[mi] if skip_dma else mbufs[mi]
            # exact shapes end to end: (m, n) @ (n,) is the same XLA dot the
            # per-node template issues, hence bitwise at float32.
            acc = tile[...] @ slots[instr.src[0]][0, :]
            if bias_ci is not None:
                acc = jnp.add(acc, crefs[bias_ci][0, :])
            slots[instr.dst][...] = acc.reshape(1, -1)
        elif op == "REQUANTIZE":
            kind, sh = instr.operand
            x = slots[instr.src[0]][...]
            if kind == "rows":           # per-channel: one shift per row
                y = requantize_rows(x, crefs[sh][0, :], seg.bits)
            else:
                y = requantize_core(x, sh, seg.bits)
            slots[instr.dst][...] = y.astype(carrier)
        elif op == "ARGMAX":
            # directly on the carrier: the dequantize scale is a positive
            # power of two (strictly monotone), so the index — ties included
            # — matches argmax over the dequantized floats bitwise.
            x = slots[instr.src[0]][0, :]
            slots[instr.dst][...] = jnp.argmax(x).reshape(1, 1).astype(carrier)
        elif op == "REDUCE":
            kind, e_in, e_out = instr.operand
            x = dq(slots[instr.src[0]][0, :], e_in)
            r = _REDUCE_F[kind](x, axis=-1)
            slots[instr.dst][...] = q(r, e_out).reshape(1, 1).astype(carrier)
        elif op == "SQL2":
            mi, e_in, e_out = instr.operand
            if not skip_dma:
                dma(copies.pop(mi).wait)
            pts = (mats[mi] if skip_dma else mbufs[mi])[...]
            x = dq(slots[instr.src[0]][0, :], e_in)
            diff = pts - x[:, None]
            acc = jnp.sum(diff * diff, axis=0)
            slots[instr.dst][...] = q(acc, e_out).reshape(1, -1).astype(carrier)
        elif op == "DOT":
            e_a, e_b, e_out = instr.operand
            a = dq(slots[instr.src[0]][0, :], e_a)
            b = dq(slots[instr.src[1]][0, :], e_b)
            r = jnp.dot(a, b)
            slots[instr.dst][...] = q(r, e_out).reshape(1, 1).astype(carrier)
        elif op == "ELEMENTWISE":
            stage, vec_cis = instr.operand
            x = slots[instr.src[0]][...]
            extras = [slots[s][...] for s in instr.src[1:]]
            if seg.quantized:
                vv = [crefs[ci][...] for ci in vec_cis]
                y = apply_stage_q(x, stage, vv, extras, seg.bits)
            else:
                if stage[0] in _VEC_STAGES:
                    stage = (stage[0], crefs[vec_cis[0]][...])
                y = apply_stage(x, stage, extras)
            slots[instr.dst][...] = y
        elif op == "STORE":
            oref = outs[instr.operand]
            oref[...] = slots[instr.src[0]][...].astype(oref.dtype)
        else:
            raise ValueError(f"unknown megakernel op {op!r}")


_launch_cache: dict[tuple[int, bool, int | None], Any] = {}


def _launch_pools(seg: MegakernelSegment):
    """Host-side const/matrix pools.  They stay numpy: the launch builders
    may first run inside an outer trace (vmap/jit of the whole program), and
    any jnp op here would bake that trace's tracers into the cached
    closure."""
    np_carrier = np.int32 if seg.quantized else np.float32
    crows = [np.asarray(c, np_carrier).reshape(1, -1) for c in seg.consts]
    mats = [np.asarray(m) for m in seg.matrices]
    return crows, mats


def _build_launch(seg: MegakernelSegment, interpret: bool):
    """Build (once per segment) the jitted single-launch callable.

    The instruction stream, const pool and matrix operands are static — the
    accelerator program is compiled exactly once and then invoked per
    sample, so the launch is traced once and cached; without this every
    eager call would re-trace the whole ``pallas_call``.  In interpret mode
    the DMA emulation buffers are dropped entirely (see ``skip_dma``)."""
    carrier = jnp.int32 if seg.quantized else jnp.float32
    out_dts = _seg_out_dtypes(seg)
    crows, mats = _launch_pools(seg)
    kernel = functools.partial(_segment_kernel, seg=seg, skip_dma=interpret)
    scratch = [pltpu.VMEM((1, w), carrier) for w in seg.slot_widths]
    if not interpret:
        scratch += [pltpu.VMEM(m.shape, m.dtype) for m in mats]
        scratch += [pltpu.SemaphoreType.DMA for _ in mats]
    call = pl.pallas_call(
        kernel,
        in_specs=(
            [pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.VMEM)
             for _ in range(len(seg.in_refs) + len(crows))]
            + [pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY) for _ in mats]
        ),
        out_shape=[jax.ShapeDtypeStruct((1, w), dt)
                   for w, dt in zip(seg.out_widths, out_dts)],
        scratch_shapes=scratch,
        interpret=interpret,
    )

    def launch(*xs):
        outs = call(*xs, *crows, *mats)
        if not isinstance(outs, (list, tuple)):
            outs = [outs]
        return [o[0] for o in outs]

    return jax.jit(launch)


def _build_launch_grid(seg: MegakernelSegment, interpret: bool, nb: int):
    """Build the batch-grid launch: ``grid=(nb,)``, one kernel invocation
    per sample, matrices DMA'd into VMEM once on grid step 0 and shared by
    every later step (grid steps are sequential on the same core).  This is
    the one-launch-per-bucket lane: the whole bucket costs a single
    ``pallas_call`` per segment instead of ``nb`` vmapped launches."""
    carrier = jnp.int32 if seg.quantized else jnp.float32
    out_dts = _seg_out_dtypes(seg)
    crows, mats = _launch_pools(seg)
    kernel = functools.partial(_segment_kernel, seg=seg, skip_dma=interpret,
                               grid=True)
    scratch = [pltpu.VMEM((1, w), carrier) for w in seg.slot_widths]
    if not interpret:
        scratch += [pltpu.VMEM(m.shape, m.dtype) for m in mats]
        scratch += [pltpu.SemaphoreType.DMA for _ in mats]
    # every in_ref is materialized by exactly one LOAD_VEC ("in", ii), so the
    # slot it fills gives the input's (flattened) width.
    in_w = {ins.operand[1]: seg.slot_widths[ins.dst]
            for ins in seg.instrs
            if ins.op == "LOAD_VEC" and ins.operand[0] == "in"}
    call = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=(
            # per-sample input rows: grid step i sees row i.
            [pl.BlockSpec((1, in_w[ii]), lambda i: (i, 0),
                          memory_space=pltpu.TPUMemorySpace.VMEM)
             for ii in range(len(seg.in_refs))]
            # const rows are shared: every step maps to row 0.
            + [pl.BlockSpec((1, c.shape[1]), lambda i: (0, 0),
                            memory_space=pltpu.TPUMemorySpace.VMEM)
               for c in crows]
            # matrices stay whole in ANY; the kernel DMAs them on step 0.
            + [pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)
               for _ in mats]
        ),
        out_shape=[jax.ShapeDtypeStruct((nb, w), dt)
                   for w, dt in zip(seg.out_widths, out_dts)],
        out_specs=[pl.BlockSpec((1, w), lambda i: (i, 0),
                                memory_space=pltpu.TPUMemorySpace.VMEM)
                   for w in seg.out_widths],
        scratch_shapes=scratch,
        interpret=interpret,
    )

    def launch(*xs):
        outs = call(*xs, *crows, *mats)
        if not isinstance(outs, (list, tuple)):
            outs = [outs]
        return list(outs)

    return jax.jit(launch)


def _cached_launch(seg: MegakernelSegment, interpret: bool,
                   nb: int | None = None):
    key = (id(seg), interpret, nb)
    fn = _launch_cache.get(key)
    if fn is None:
        fn = (_build_launch(seg, interpret) if nb is None
              else _build_launch_grid(seg, interpret, nb))
        _launch_cache[key] = fn
        sid = id(seg)
        weakref.finalize(
            seg,
            lambda: [_launch_cache.pop(k, None)
                     for k in list(_launch_cache) if k[0] == sid],
        )
    return fn


def run_segment(
    seg: MegakernelSegment,
    inputs: Sequence[jax.Array],
    *,
    interpret: bool | None = None,
) -> list[jax.Array]:
    """Execute one segment in a single ``pallas_call``.

    ``inputs`` are the env values of ``seg.in_refs`` in order (any shape —
    flattened to the feature axis here); returns one flat value per
    ``seg.out_refs`` (the caller reshapes via ``seg.out_shapes``).  Int-lane
    inputs may be narrow or int32; outputs are the narrow activation dtype.
    The launch is traced once per segment and cached (the stream is static),
    so repeated eager calls pay only one XLA dispatch.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    xs = [jnp.asarray(x).reshape(1, -1) for x in inputs]
    return _cached_launch(seg, interpret)(*xs)


def run_segment_grid(
    seg: MegakernelSegment,
    inputs: Sequence[jax.Array],
    *,
    interpret: bool | None = None,
) -> list[jax.Array]:
    """Execute one segment for a whole bucket in a single ``pallas_call``.

    ``inputs`` are batched env values of ``seg.in_refs`` (leading batch
    axis, any trailing shape — flattened to ``(nb, width)`` here).  The
    batch axis rides the Pallas grid: ``grid=(nb,)`` with per-sample rows
    selected by ``program_id``, and matrix DMAs issued only on grid step 0
    so every matrix crosses HBM→VMEM once per bucket.  Returns one
    ``(nb, width)`` value per ``seg.out_refs``; bitwise identical to
    ``jax.vmap(run_segment)`` on every lane.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    nb = int(jnp.asarray(inputs[0]).shape[0])
    xs = [jnp.asarray(x).reshape(nb, -1) for x in inputs]
    return _cached_launch(seg, interpret, nb)(*xs)
