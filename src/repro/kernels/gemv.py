"""Dense tiled GEMV / matmul — Pallas TPU kernels.

The dense counterparts of :mod:`repro.kernels.spmv`: batched matrix–vector
(``y = x @ W.T``, the gemv DFG template) and a generic tiled matmul.  Both use
the standard TPU schedule — grid over (output tiles × contraction tiles) with
the trailing contraction dimension sequential, fp32 accumulation in a VMEM
scratch tile, output written on the last contraction step.  MXU-aligned
(128 × 128) tiles by default.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["gemv", "matmul"]

DEFAULT_T = 128


def _matmul_kernel(a_ref, b_ref, out_ref, acc_ref, *, transpose_b: bool):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    b = b_ref[...]
    if transpose_b:
        acc_ref[...] += jax.lax.dot_general(
            a, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
    else:
        acc_ref[...] += jax.lax.dot_general(
            a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "transpose_b", "interpret")
)
def _matmul_call(a, b, *, bm, bn, bk, transpose_b, interpret):
    M, K = a.shape
    N = b.shape[0] if transpose_b else b.shape[1]
    b_spec = (
        pl.BlockSpec((bn, bk), lambda i, j, k: (j, k))
        if transpose_b
        else pl.BlockSpec((bk, bn), lambda i, j, k: (k, j))
    )
    return pl.pallas_call(
        functools.partial(_matmul_kernel, transpose_b=transpose_b),
        grid=(M // bm, N // bn, K // bk),
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)), b_spec],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)


def _pad2(x: jax.Array, m0: int, m1: int) -> jax.Array:
    return jnp.pad(x, ((0, (-x.shape[0]) % m0), (0, (-x.shape[1]) % m1)))


def matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    transpose_b: bool = False,
    tile: int = DEFAULT_T,
    interpret: bool | None = None,
) -> jax.Array:
    """Tiled ``a @ b`` (or ``a @ b.T``) with fp32 accumulation."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    M, K = a.shape
    N = b.shape[0] if transpose_b else b.shape[1]
    bm = min(tile, max(8, 1 << (M - 1).bit_length()))
    bn = min(tile, max(8, 1 << (N - 1).bit_length()))
    bk = min(tile, max(8, 1 << (K - 1).bit_length()))
    ap = _pad2(a, bm, bk)
    bp = _pad2(b, bn, bk) if transpose_b else _pad2(b, bk, bn)
    out = _matmul_call(ap, bp, bm=bm, bn=bn, bk=bk, transpose_b=transpose_b,
                       interpret=interpret)
    return out[:M, :N]


def gemv(w: jax.Array, x: jax.Array, *, tile: int = DEFAULT_T,
         interpret: bool | None = None) -> jax.Array:
    """Batched GEMV: ``w`` (m, n), ``x`` (B, n) → (B, m) = x @ w.T."""
    return matmul(x, w, transpose_b=True, tile=tile, interpret=interpret)
