"""Zamba2-7B — 81L d_model=3584 (Mamba2 backbone, ssm_state=64) with a
*shared* attention block (32H, kv=32) + MLP (d_ff=14336) applied every 6th
layer at 2×d_model over concat(hidden, initial embedding), vocab 32000.
[arXiv:2411.15242; unverified]

Structure simplification (DESIGN.md §Arch-applicability): real Zamba2-7B
alternates two shared blocks with per-application LoRA deltas; here a single
shared block (weights literally shared across its 13 applications) is
applied every ``hybrid_attn_every=6`` layers — 68 Mamba2 layers + 13 shared
applications = 81 block applications.  At long_500k the shared attention
uses a 4096-token sliding-window ring cache (SSM state is O(1)).
"""

from repro.configs.registry import ArchSpec, default_skips
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_head=224,               # shared block runs at 2·d_model / 32 heads
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    hybrid_attn_every=6,
)

SMOKE = ModelConfig(
    name="zamba2-smoke",
    family="hybrid",
    n_layers=7,
    d_model=32,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=64,
    vocab_size=256,
    ssm_state=8,
    ssm_head_dim=8,
    ssm_chunk=8,
    hybrid_attn_every=3,
    act_dtype="float32",
    kv_chunk=32,
)

SPEC = ArchSpec(
    arch_id="zamba2-7b",
    source="[arXiv:2411.15242; unverified]",
    model=CONFIG,
    smoke=SMOKE,
    train_microbatches=8,
    long_ctx_window=4096,
    skip_cells=default_skips("hybrid"),
)
