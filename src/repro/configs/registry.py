"""Architecture registry + shape cells.

Every assigned architecture registers an :class:`ArchSpec`: the exact
full-size :class:`~repro.models.transformer.ModelConfig` from the public
config, a *reduced* smoke config of the same family (exercised on CPU in
tests), and per-shape-cell metadata (microbatching, long-context window,
documented skips).

Shape cells (fixed by the assignment):

    train_4k      seq 4,096   × global batch 256   → lowers ``train_step``
    prefill_32k   seq 32,768  × global batch 32    → lowers ``prefill_step``
    decode_32k    seq 32,768  × global batch 128   → lowers ``serve_step``
                  (1 new token against a 32k KV cache)
    long_500k     seq 524,288 × global batch 1     → ``serve_step``; needs
                  sub-quadratic attention → run only for ssm/hybrid archs,
                  skip (with reason) for pure full-attention archs.
"""

from __future__ import annotations

import dataclasses
import importlib

from repro.models.transformer import ModelConfig

__all__ = ["ArchSpec", "ShapeCell", "SHAPES", "ARCH_IDS", "get_arch",
           "all_archs", "cells_for"]


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str               # train | prefill | decode
    seq_len: int
    global_batch: int
    long_context: bool = False


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524_288, 1, long_context=True),
}


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    source: str                      # public provenance ([arXiv/hf; tier])
    model: ModelConfig
    smoke: ModelConfig
    train_microbatches: int = 8      # gradient-accumulation steps for train_4k
    long_ctx_window: int = 4096      # sliding window used at long_500k (hybrid)
    skip_cells: dict[str, str] = dataclasses.field(default_factory=dict)

    def cell_config(self, cell: ShapeCell) -> ModelConfig:
        """ModelConfig specialized for one shape cell."""
        cfg = self.model
        if cell.long_context and cfg.family == "hybrid":
            cfg = dataclasses.replace(cfg, attn_window=self.long_ctx_window)
        if cell.kind != "train":
            # inference: bf16 weights, no remat (fp32 masters are train-only)
            cfg = dataclasses.replace(cfg, remat=False, param_dtype="bfloat16")
        elif cell.seq_len <= 4096:
            # flash kv-chunking exists to bound long-sequence score memory;
            # at ≤4k the chunk loop is pure overhead (stacked per-chunk masks
            # + carried fp32 stats — measured 11% of the memory term,
            # EXPERIMENTS.md §Perf) — run attention single-chunk.
            cfg = dataclasses.replace(cfg, kv_chunk=max(cfg.kv_chunk,
                                                        cell.seq_len))
        return cfg


_FULL_ATTN_SKIP = (
    "long_500k needs sub-quadratic attention history; this arch is pure "
    "full-attention (O(S) KV history per layer) — skipped per the shape "
    "rule, recorded in DESIGN.md §Arch-applicability"
)

ARCH_IDS: list[str] = [
    "olmoe-1b-7b",
    "deepseek-v2-236b",
    "musicgen-medium",
    "internvl2-26b",
    "granite-8b",
    "command-r-35b",
    "codeqwen1.5-7b",
    "qwen2.5-3b",
    "zamba2-7b",
    "mamba2-1.3b",
]

_MODULES = {a: "repro.configs." + a.replace("-", "_").replace(".", "_")
            for a in ARCH_IDS}

_CACHE: dict[str, ArchSpec] = {}


def get_arch(arch_id: str) -> ArchSpec:
    if arch_id not in _CACHE:
        if arch_id not in _MODULES:
            raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
        mod = importlib.import_module(_MODULES[arch_id])
        spec = mod.SPEC
        assert spec.arch_id == arch_id
        _CACHE[arch_id] = spec
    return _CACHE[arch_id]


def all_archs() -> list[ArchSpec]:
    return [get_arch(a) for a in ARCH_IDS]


def cells_for(spec: ArchSpec) -> list[ShapeCell]:
    """The runnable shape cells for an arch (skips excluded)."""
    return [c for n, c in SHAPES.items() if n not in spec.skip_cells]


def default_skips(family: str) -> dict[str, str]:
    if family in ("ssm", "hybrid"):
        return {}
    return {"long_500k": _FULL_ATTN_SKIP}
