"""MLPerf-Tiny-shaped ONNX workloads — the workload class small-FPGA
toolchains are judged on (hls4ml / MLPerf-Tiny codesign, PAPERS.md).

Two synthetic-weight fixtures checked in as real ``.onnx`` graphs (emitted
by the dependency-free writer in :mod:`repro.frontends.onnx_proto`, and
regenerable bit-for-bit with :func:`regenerate`):

* ``kws_mlp`` — keyword-spotting-style MLP over a 49×10 MFCC patch:
  Flatten → Gemm(490→128) → Relu → MatMul+Add(128→128) → Relu →
  Gemm(128→12) → Softmax.  Exercises Flatten / Gemm / MatMul / Add.

* ``tiny_cnn`` — small image classifier over 3×16×16:
  Conv(3→8, 3×3, pad 1) → BatchNorm → Relu → MaxPool 2×2 →
  Conv(8→16, 3×3, pad 1) → Relu → AveragePool 2×2 → Reshape → Gemm(256→10)
  → Softmax.  Exercises Conv / BatchNorm folding / both pools / Reshape.

Weights are deterministic (fixed seed, He-ish scaling) — these fixtures
gate the *compiler* (lane parity, int8 accuracy drop, serving), not model
quality.  ``sample_inputs`` draws the matching standardized input batches;
``teacher_labels`` labels a batch with the float32 model's argmax, the
reference the int8 accuracy-drop gate compares against.
"""

from __future__ import annotations

import os
from typing import Any

import numpy as np

from repro.core.dfg import DFG
from repro.frontends import onnx_proto as op_
from repro.frontends.onnx_importer import import_onnx

__all__ = ["WORKLOADS", "fixture_path", "model_bytes", "build",
           "input_name", "sample_inputs", "teacher_labels", "regenerate"]

WORKLOADS = ("kws_mlp", "tiny_cnn")

_FIXTURE_DIR = os.path.join(os.path.dirname(__file__), "fixtures",
                            "mlperf_tiny")

# (per-sample input shape, classes) per workload
_SHAPES: dict[str, tuple[tuple[int, ...], int]] = {
    "kws_mlp": ((49, 10), 12),
    "tiny_cnn": ((3, 16, 16), 10),
}


def fixture_path(name: str) -> str:
    if name not in WORKLOADS:
        raise KeyError(f"unknown MLPerf-Tiny workload {name!r}; "
                       f"have {WORKLOADS}")
    return os.path.join(_FIXTURE_DIR, f"{name}.onnx")


def model_bytes(name: str) -> bytes:
    with open(fixture_path(name), "rb") as f:
        return f.read()


def build(name: str) -> DFG:
    """Checked-in fixture → per-sample DFG through the ONNX importer."""
    return import_onnx(model_bytes(name), name=name)


def input_name(name: str) -> str:
    return "input"


def sample_inputs(name: str, n: int = 256, seed: int = 1) -> np.ndarray:
    """Deterministic standardized input batch ``(n, *per_sample_shape)``."""
    shape, _ = _SHAPES[name]
    rng = np.random.default_rng(seed + {w: i for i, w in
                                        enumerate(WORKLOADS)}[name] * 1000)
    return rng.standard_normal((n,) + shape).astype(np.float32)


def teacher_labels(program: Any, x: np.ndarray) -> np.ndarray:
    """Argmax labels of a compiled program over batch ``x`` — the float32
    teacher the int8 accuracy gate scores against."""
    out = program.batch(max_batch=len(x), mode="map")(input=x)
    (probs,) = out.values()
    return np.argmax(np.asarray(probs), axis=-1)


# ============================================================== generator
def _glorot(rng: np.random.Generator, *shape: int) -> np.ndarray:
    fan_in = int(np.prod(shape[1:])) or 1
    return (rng.standard_normal(shape) / np.sqrt(fan_in)).astype(np.float32)


def _kws_mlp() -> bytes:
    rng = np.random.default_rng(2107)
    shape, classes = _SHAPES["kws_mlp"]
    n_in = int(np.prod(shape))
    w1, b1 = _glorot(rng, 128, n_in), _glorot(rng, 128)
    w2, b2 = _glorot(rng, 128, 128), _glorot(rng, 128)
    # trained classifier heads separate classes decisively; raw random
    # weights don't.  Widen the head so the fixture's argmax is stable the
    # way a real model's is — the int8 gate scores label agreement, and a
    # near-tie head would measure tie-breaking noise, not quantization.
    w3, b3 = 3.0 * _glorot(rng, classes, 128), _glorot(rng, classes)
    nodes = [
        op_.make_node("Flatten", ["input"], ["flat"], name="flatten0", axis=1),
        op_.make_node("Gemm", ["flat", "w1", "b1"], ["h1"], name="fc1",
                      alpha=1.0, beta=1.0, transB=1),
        op_.make_node("Relu", ["h1"], ["a1"], name="relu1"),
        op_.make_node("MatMul", ["a1", "w2t"], ["h2"], name="fc2"),
        op_.make_node("Add", ["h2", "b2"], ["h2b"], name="fc2_bias"),
        op_.make_node("Relu", ["h2b"], ["a2"], name="relu2"),
        op_.make_node("Gemm", ["a2", "w3", "b3"], ["logits"], name="fc3",
                      alpha=1.0, beta=1.0, transB=1),
        op_.make_node("Softmax", ["logits"], ["probs"], name="softmax0",
                      axis=1),
    ]
    inits = [
        op_.np_to_tensor("w1", w1), op_.np_to_tensor("b1", b1),
        op_.np_to_tensor("w2t", np.ascontiguousarray(w2.T)),
        op_.np_to_tensor("b2", b2),
        op_.np_to_tensor("w3", w3), op_.np_to_tensor("b3", b3),
    ]
    return op_.build_model(
        graph_name="kws_mlp",
        nodes=nodes,
        inputs=[op_.value_info("input", ("N",) + shape)],
        outputs=[op_.value_info("probs", ("N", classes))],
        initializers=inits,
    )


def _tiny_cnn() -> bytes:
    rng = np.random.default_rng(653)
    shape, classes = _SHAPES["tiny_cnn"]
    k1 = _glorot(rng, 8, shape[0], 3, 3)
    bn_scale = (1.0 + 0.1 * rng.standard_normal(8)).astype(np.float32)
    bn_b = (0.1 * rng.standard_normal(8)).astype(np.float32)
    bn_mean = (0.05 * rng.standard_normal(8)).astype(np.float32)
    bn_var = (1.0 + 0.1 * rng.random(8)).astype(np.float32)
    k2, c2b = _glorot(rng, 16, 8, 3, 3), _glorot(rng, 16)
    flat = 16 * (shape[1] // 4) * (shape[2] // 4)
    # widened head: see _kws_mlp — argmax stability like a trained model's
    w, b = 3.0 * _glorot(rng, classes, flat), _glorot(rng, classes)
    nodes = [
        op_.make_node("Conv", ["input", "k1"], ["c1"], name="conv1",
                      kernel_shape=(3, 3), strides=(1, 1),
                      pads=(1, 1, 1, 1)),
        op_.make_node("BatchNormalization",
                      ["c1", "bn_s", "bn_b", "bn_m", "bn_v"], ["n1"],
                      name="bn1", epsilon=1e-5),
        op_.make_node("Relu", ["n1"], ["a1"], name="relu1"),
        op_.make_node("MaxPool", ["a1"], ["p1"], name="pool1",
                      kernel_shape=(2, 2), strides=(2, 2)),
        op_.make_node("Conv", ["p1", "k2", "c2b"], ["c2"], name="conv2",
                      kernel_shape=(3, 3), strides=(1, 1),
                      pads=(1, 1, 1, 1)),
        op_.make_node("Relu", ["c2"], ["a2"], name="relu2"),
        op_.make_node("AveragePool", ["a2"], ["p2"], name="pool2",
                      kernel_shape=(2, 2), strides=(2, 2)),
        op_.make_node("Reshape", ["p2", "rshape"], ["flat"], name="reshape0"),
        op_.make_node("Gemm", ["flat", "w", "b"], ["logits"], name="fc",
                      alpha=1.0, beta=1.0, transB=1),
        op_.make_node("Softmax", ["logits"], ["probs"], name="softmax0",
                      axis=1),
    ]
    inits = [
        op_.np_to_tensor("k1", k1),
        op_.np_to_tensor("bn_s", bn_scale), op_.np_to_tensor("bn_b", bn_b),
        op_.np_to_tensor("bn_m", bn_mean), op_.np_to_tensor("bn_v", bn_var),
        op_.np_to_tensor("k2", k2), op_.np_to_tensor("c2b", c2b),
        op_.np_to_tensor("rshape", np.asarray([-1, flat], np.int64)),
        op_.np_to_tensor("w", w), op_.np_to_tensor("b", b),
    ]
    return op_.build_model(
        graph_name="tiny_cnn",
        nodes=nodes,
        inputs=[op_.value_info("input", ("N",) + shape)],
        outputs=[op_.value_info("probs", ("N", classes))],
        initializers=inits,
    )


_GENERATORS = {"kws_mlp": _kws_mlp, "tiny_cnn": _tiny_cnn}


def regenerate() -> dict[str, str]:
    """Rewrite the checked-in fixtures (deterministic — same bytes every
    run).  Returns name → path."""
    os.makedirs(_FIXTURE_DIR, exist_ok=True)
    out = {}
    for name, gen in _GENERATORS.items():
        path = fixture_path(name)
        with open(path, "wb") as f:
            f.write(gen())
        out[name] = path
    return out


if __name__ == "__main__":
    for name, path in regenerate().items():
        print(f"{name}: {path} ({os.path.getsize(path)} bytes)")
