"""Command-R-35B — 40L d_model=8192 64H (kv=8) d_ff=22528, vocab 256000 —
GQA, no-bias.  [hf:CohereForAI/c4ai-command-r-v01; unverified]

The 256k-vocab lm_head/embedding is the worked example of MAFIA-style
criticality-driven sharding: the planner's DFG optimizer assigns the logits
node the maximum PF (vocab fully sharded over the model axis).
"""

from repro.configs.registry import ArchSpec, default_skips
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=22528,
    vocab_size=256000,
)

SMOKE = ModelConfig(
    name="command-r-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    d_head=8,
    d_ff=128,
    vocab_size=512,
    act_dtype="float32",
    kv_chunk=32,
)

SPEC = ArchSpec(
    arch_id="command-r-35b",
    source="[hf:CohereForAI/c4ai-command-r-v01; unverified]",
    model=CONFIG,
    smoke=SMOKE,
    train_microbatches=16,
    skip_cells=default_skips("dense"),
)
