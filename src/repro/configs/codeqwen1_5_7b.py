"""CodeQwen1.5-7B — 32L d_model=4096 32H (kv=32, MHA) d_ff=13440,
vocab 92416 — qwen1.5-arch (QKV bias).  [hf:Qwen/CodeQwen1.5-7B; hf]"""

from repro.configs.registry import ArchSpec, default_skips
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_head=128,
    d_ff=13440,
    vocab_size=92416,
    qkv_bias=True,
)

SMOKE = ModelConfig(
    name="codeqwen-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=128,
    vocab_size=256,
    qkv_bias=True,
    act_dtype="float32",
    kv_chunk=32,
)

SPEC = ArchSpec(
    arch_id="codeqwen1.5-7b",
    source="[hf:Qwen/CodeQwen1.5-7B; hf]",
    model=CONFIG,
    smoke=SMOKE,
    train_microbatches=8,
    skip_cells=default_skips("dense"),
)
