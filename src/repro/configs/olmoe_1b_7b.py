"""OLMoE-1B-7B — 16L d_model=2048 16H (kv=16) expert d_ff=1024, vocab 50304,
MoE 64 experts top-8.  [arXiv:2409.02060; hf]"""

from repro.configs.registry import ArchSpec, default_skips
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=0,
    vocab_size=50304,
    n_experts=64,
    experts_per_token=8,
    d_ff_expert=1024,
)

SMOKE = ModelConfig(
    name="olmoe-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=0,
    vocab_size=256,
    n_experts=8,
    experts_per_token=2,
    d_ff_expert=32,
    act_dtype="float32",
    kv_chunk=32,
)

SPEC = ArchSpec(
    arch_id="olmoe-1b-7b",
    source="[arXiv:2409.02060; hf]",
    model=CONFIG,
    smoke=SMOKE,
    train_microbatches=8,
    skip_cells=default_skips("moe"),
)
