"""The paper's own benchmark configs: 20 DFGs = {BONSAI, PROTONN} × 10
datasets (Table I).  Each entry builds (trains, if requested) the model and
returns its MAFIA DFG — the input of every Fig. 3 / Fig. 4 comparison.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from repro.core.dfg import DFG
from repro.data.datasets import TABLE_I, DatasetSpec, get_spec, make_dataset
from repro.models import bonsai, protonn

__all__ = ["ClassicalBenchmark", "BENCHMARKS", "TRAIN_SPLIT", "build",
           "training_split"]

# Rows build(trained=True) fits on — int8 calibration and the quantization
# benchmark reuse this so their split is exactly the training split.
TRAIN_SPLIT = 1024


@dataclasses.dataclass(frozen=True)
class ClassicalBenchmark:
    name: str                # e.g. "bonsai/usps-b"
    algo: str                # bonsai | protonn
    dataset: DatasetSpec

    @property
    def mcu_baseline_us(self) -> float:
        return (self.dataset.mcu_bonsai_us if self.algo == "bonsai"
                else self.dataset.mcu_protonn_us)


BENCHMARKS: list[ClassicalBenchmark] = [
    ClassicalBenchmark(f"{algo}/{spec.name}", algo, spec)
    for algo in ("bonsai", "protonn")
    for spec in TABLE_I
]


def _resolve(bench: ClassicalBenchmark | str) -> ClassicalBenchmark:
    if isinstance(bench, str):
        algo, ds = bench.split("/")
        return ClassicalBenchmark(bench, algo, get_spec(ds))
    return bench


def training_split(bench: ClassicalBenchmark | str, seed: int = 0):
    """(X, y) of the exact rows — same draw, same standardization stats —
    that ``build(trained=True)`` fits on; the int8 calibration source."""
    bench = _resolve(bench)
    Xtr, ytr, _, _ = make_dataset(bench.dataset, n_train=TRAIN_SPLIT, seed=seed)
    return Xtr, ytr


def build(
    bench: ClassicalBenchmark | str,
    *,
    trained: bool = False,
    seed: int = 0,
) -> tuple[DFG, dict[str, Any], Any]:
    """Build (dfg, params, config) for one benchmark; optionally fit on the
    synthetic dataset first (slow — tests/benches default to random init,
    which exercises identical shapes/sparsity)."""
    bench = _resolve(bench)
    mod = bonsai if bench.algo == "bonsai" else protonn
    cfg = mod.from_spec(bench.dataset)
    if trained:
        Xtr, ytr = training_split(bench, seed=seed)
        params = mod.train(cfg, Xtr, ytr, steps=120, seed=seed)
    else:
        params = mod.init_params(cfg, seed=seed)
    return mod.build_dfg(params, cfg, name=bench.name.replace("/", "_")), params, cfg
