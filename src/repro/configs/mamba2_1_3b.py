"""Mamba2-1.3B — 48L d_model=2048, attention-free SSD (state-space duality),
ssm_state=128, vocab 50280 (padded to 50304 for even vocab sharding).
[arXiv:2405.21060; unverified]

MAFIA applicability note (DESIGN.md §Arch-applicability): the paper's
*attention-sharding* aspects are inapplicable (no KV); per-node PF
assignment applies to the SSD block matmuls and projections, which is what
the sharding planner optimizes here.
"""

from repro.configs.registry import ArchSpec, default_skips
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
)

SMOKE = ModelConfig(
    name="mamba2-smoke",
    family="ssm",
    n_layers=2,
    d_model=32,
    vocab_size=256,
    ssm_state=8,
    ssm_head_dim=8,
    ssm_chunk=8,
    act_dtype="float32",
)

SPEC = ArchSpec(
    arch_id="mamba2-1.3b",
    source="[arXiv:2405.21060; unverified]",
    model=CONFIG,
    smoke=SMOKE,
    train_microbatches=4,
    skip_cells=default_skips("ssm"),
)
