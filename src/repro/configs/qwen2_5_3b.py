"""Qwen2.5-3B — 36L d_model=2048 16H (kv=2) d_ff=11008, vocab 151936 —
GQA with QKV bias.  [hf:Qwen/Qwen2.5-0.5B; hf]"""

from repro.configs.registry import ArchSpec, default_skips
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b",
    family="dense",
    n_layers=36,
    d_model=2048,
    n_heads=16,
    n_kv_heads=2,
    d_head=128,
    d_ff=11008,
    vocab_size=151936,
    qkv_bias=True,
)

SMOKE = ModelConfig(
    name="qwen2.5-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    d_head=8,
    d_ff=128,
    vocab_size=256,
    qkv_bias=True,
    act_dtype="float32",
    kv_chunk=32,
)

SPEC = ArchSpec(
    arch_id="qwen2.5-3b",
    source="[hf:Qwen/Qwen2.5-0.5B; hf]",
    model=CONFIG,
    smoke=SMOKE,
    train_microbatches=4,
    skip_cells=default_skips("dense"),
)
