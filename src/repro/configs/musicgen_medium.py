"""MusicGen-medium — 48L d_model=1536 24H (kv=24, plain MHA) d_ff=6144,
vocab 2048 — decoder-only over EnCodec tokens.  [arXiv:2306.05284; hf]

Modality stub (per assignment): the EnCodec audio frontend is NOT
implemented; the backbone consumes precomputed EnCodec *token* streams
(vocab 2048).  The real model sums 4 codebook embeddings per frame — the
stub treats the stream as a single token sequence, which preserves every
backbone shape.  RoPE replaces MusicGen's sinusoidal embedding (uniform
backbone; noted in DESIGN.md §hardware-adaptation).
"""

from repro.configs.registry import ArchSpec, default_skips
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="dense",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_head=64,
    d_ff=6144,
    vocab_size=2048,
    modality="audio_tokens",
)

SMOKE = ModelConfig(
    name="musicgen-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=128,
    vocab_size=256,
    modality="audio_tokens",
    act_dtype="float32",
    kv_chunk=32,
)

SPEC = ArchSpec(
    arch_id="musicgen-medium",
    source="[arXiv:2306.05284; hf]",
    model=CONFIG,
    smoke=SMOKE,
    train_microbatches=4,
    skip_cells=default_skips("dense"),
)
