"""DeepSeek-V2-236B — 60L d_model=5120 128H, MLA kv_lora=512 q_lora=1536
(qk-nope 128 + decoupled rope 64 per head), vocab 102400, MoE 2 shared + 160
routed top-6, expert d_ff=1536.  [arXiv:2405.04434; hf]

Simplification recorded in DESIGN.md: the real model's first layer uses a
dense FFN; here all 60 layers are uniform MoE so the layer stack scans — the
parameter count difference is <0.5%.
"""

from repro.configs.registry import ArchSpec, default_skips
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_head=128,               # qk-nope / value dims per head
    d_ff=0,
    vocab_size=102400,
    n_experts=160,
    experts_per_token=6,
    n_shared_experts=2,
    d_ff_expert=1536,
    use_mla=True,
    kv_lora_rank=512,
    q_lora_rank=1536,
    d_rope=64,
)

SMOKE = ModelConfig(
    name="deepseek-v2-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=0,
    vocab_size=256,
    n_experts=8,
    experts_per_token=2,
    n_shared_experts=2,
    d_ff_expert=32,
    use_mla=True,
    kv_lora_rank=32,
    q_lora_rank=24,
    d_rope=8,
    act_dtype="float32",
    kv_chunk=32,
)

SPEC = ArchSpec(
    arch_id="deepseek-v2-236b",
    source="[arXiv:2405.04434; hf]",
    model=CONFIG,
    smoke=SMOKE,
    train_microbatches=16,
    skip_cells=default_skips("moe"),
)
