"""Architecture configs: one module per assigned architecture + the paper's
own classical-ML benchmark configs (:mod:`repro.configs.classical`)."""

from repro.configs.registry import (
    ARCH_IDS,
    SHAPES,
    ArchSpec,
    ShapeCell,
    all_archs,
    cells_for,
    get_arch,
)

__all__ = ["ARCH_IDS", "SHAPES", "ArchSpec", "ShapeCell", "all_archs",
           "cells_for", "get_arch"]
