"""InternVL2-26B — LM backbone (InternLM2-20B): 48L d_model=6144 48H (kv=8)
d_ff=16384, vocab 92553.  [arXiv:2404.16821; hf]

Modality stub (per assignment): the InternViT-6B vision tower is NOT
implemented; ``input_specs()`` supplies precomputed patch embeddings
(B, vision_prefix_len, d_model) that are prepended to the token embeddings.
The loss masks the vision prefix.
"""

from repro.configs.registry import ArchSpec, default_skips
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="dense",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_head=128,
    d_ff=16384,
    vocab_size=92553,
    modality="vision_prefix",
    vision_prefix_len=1024,          # ~4 tiles × 256 patch tokens
)

SMOKE = ModelConfig(
    name="internvl2-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    d_head=8,
    d_ff=128,
    vocab_size=256,
    modality="vision_prefix",
    vision_prefix_len=8,
    act_dtype="float32",
    kv_chunk=32,
)

SPEC = ArchSpec(
    arch_id="internvl2-26b",
    source="[arXiv:2404.16821; hf]",
    model=CONFIG,
    smoke=SMOKE,
    train_microbatches=16,
    skip_cells=default_skips("dense"),
)
