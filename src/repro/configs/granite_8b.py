"""Granite-8B-Code — 36L d_model=4096 32H (kv=8) d_ff=14336, vocab 49152 —
llama-arch, code.  [arXiv:2405.04324; hf]"""

from repro.configs.registry import ArchSpec, default_skips
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14336,
    vocab_size=49152,
)

SMOKE = ModelConfig(
    name="granite-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    d_head=8,
    d_ff=128,
    vocab_size=256,
    act_dtype="float32",
    kv_chunk=32,
)

SPEC = ArchSpec(
    arch_id="granite-8b",
    source="[arXiv:2405.04324; hf]",
    model=CONFIG,
    smoke=SMOKE,
    train_microbatches=8,
    skip_cells=default_skips("dense"),
)
