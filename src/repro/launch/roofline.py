"""§Roofline term computation from a compiled dry-run artifact.

    compute term    = HLO_FLOPs / (chips × 197e12 FLOP/s)
    memory term     = HLO_bytes / (chips × 819e9 B/s)
    collective term = collective_bytes / (chips × 50e9 B/s per ICI link)

plus MODEL_FLOPS = 6·N_active·tokens (train) / 2·N_active·tokens (forward)
and the usefulness ratio MODEL_FLOPS / HLO_FLOPs (catches remat/redundancy
waste — remat pushes it below 1 by design; values ≪ 0.3 flag real waste).
"""

from __future__ import annotations

import math
from typing import Any

import jax

from repro.configs.registry import ShapeCell
from repro.core.tpu_model import TPU_V5E, dominant_term
from repro.models.transformer import ModelConfig, abstract_params

__all__ = ["n_active_params", "model_flops", "roofline_terms", "summarize"]


def n_active_params(cfg: ModelConfig) -> int:
    """Non-embedding parameters, with routed experts scaled by k/E."""
    tree = abstract_params(cfg)
    total = 0.0
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        keys = [str(getattr(k, "key", k)) for k in path]
        if keys[-1] == "embed":
            continue
        n = math.prod(leaf.shape)
        if "moe" in keys and keys[-1] in ("w_gate", "w_up", "w_down") and leaf.ndim == 4:
            n *= cfg.experts_per_token / cfg.n_experts
        total += n
    return int(total)


def model_flops(cfg: ModelConfig, cell: ShapeCell) -> float:
    n_act = n_active_params(cfg)
    if cell.kind == "train":
        tokens = cell.seq_len * cell.global_batch
        return 6.0 * n_act * tokens
    if cell.kind == "prefill":
        tokens = cell.seq_len * cell.global_batch
        return 2.0 * n_act * tokens
    # decode: one token per sequence (attention cache reads are the memory
    # term's job, not FLOPs)
    return 2.0 * n_act * cell.global_batch


def roofline_terms(
    hlo_flops: float,
    hlo_bytes: float,
    collective_bytes: float,
    n_chips: int,
    chip=TPU_V5E,
) -> dict[str, float]:
    return {
        "compute_s": hlo_flops / (n_chips * chip.peak_flops_bf16),
        "memory_s": hlo_bytes / (n_chips * chip.hbm_bw),
        "collective_s": collective_bytes / (n_chips * chip.ici_bw_per_link),
    }


def summarize(
    cfg: ModelConfig,
    cell: ShapeCell,
    hlo_cost,                     # HloCost: per-device, trip-count-aware
    n_chips: int,
) -> dict[str, Any]:
    """Roofline record from the per-device HLO cost (see hlo_analysis).

    Per-device values × n_chips = global; terms are per-device work over
    per-chip peaks (mathematically identical to global/(chips×peak)).
    """
    flops_dev = float(hlo_cost.flops)
    bytes_dev = float(hlo_cost.bytes)
    coll_dev = float(hlo_cost.collective_bytes)
    chip = TPU_V5E
    terms = {
        "compute_s": flops_dev / chip.peak_flops_bf16,
        "memory_s": bytes_dev / chip.hbm_bw,
        "collective_s": coll_dev / chip.ici_bw_per_link,
    }
    dom = dominant_term(terms)
    mf = model_flops(cfg, cell)
    flops_global = flops_dev * n_chips
    bound = max(terms.values())
    total = sum(terms.values())
    return {
        **terms,
        "dominant": dom,
        "hlo_flops_per_device": flops_dev,
        "hlo_flops_global": flops_global,
        "hlo_bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll_dev,
        "model_flops": mf,
        "useful_flops_ratio": mf / flops_global if flops_global else float("nan"),
        # step-time bounds: all-overlapped (max term) vs fully serial (sum)
        "ideal_step_s": bound,
        "serial_step_s": total,
        # fraction of the ideal the dominant term alone would achieve —
        # 1.0 means perfectly overlapped execution is bounded by one resource
        "overlap_headroom": bound / total if total else float("nan"),
        "unknown_trip_loops": hlo_cost.unknown_trip_loops,
    }
