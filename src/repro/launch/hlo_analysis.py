"""Trip-count-aware cost analysis of compiled HLO text.

``compiled.cost_analysis()`` counts each ``while`` body **once** — a
scanned-layer model under-reports FLOPs by ~n_layers× (verified on XLA CPU:
a 10-iteration ``lax.scan`` of a matmul reports exactly 1/10 the unrolled
FLOPs).  It also has no collective term.  This module re-derives all three
roofline inputs from the optimized per-device HLO module, multiplying
``while`` bodies by their trip count (XLA's ``known_trip_count`` backend
config, else the loop condition's ``compare(iv, constant)`` bound):

* **flops**       — 2 · numel(result) · contracted-size for every ``dot``
                    (recursing into fusion/while/call computations),
* **bytes**       — Σ (operands + result) per *top-level* instruction of each
                    computation; fusions count at the fusion boundary (one
                    kernel = one HBM round trip), matching the roofline model,
* **collectives** — operand bytes of all-gather / all-reduce / reduce-scatter
                    / all-to-all / collective-permute, by kind.

All values are per-device (the HLO module is the SPMD per-device program);
multiply by chip count for global numbers.
"""

from __future__ import annotations

import dataclasses
import re

__all__ = ["HloCost", "analyze_hlo", "parse_hlo_collectives", "collective_bytes",
           "xla_cost_analysis"]


def xla_cost_analysis(compiled) -> dict:
    """Normalized ``Compiled.cost_analysis()`` — always a flat dict.

    Across JAX versions ``cost_analysis()`` has returned a dict, a
    one-element list of dicts (one per program), or None.  Every caller
    here wants the single per-program dict; normalize in one place.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost) if cost else {}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
}

# instructions that move no data of their own
_FREE_OPS = {
    "parameter", "tuple", "get-tuple-element", "bitcast", "constant",
    "after-all", "partition-id", "replica-id", "iota", "rng-get-and-update-state",
    "opt-barrier",
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_TRIP_RE = re.compile(r"known_trip_count\D*?(\d+)")
_PARAM_RE = re.compile(r"%?([\w.\-]+)\s*:\s*((?:\([^)]*\))|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)")
_CALL_ATTR_RE = re.compile(
    r"(?:body|condition|to_apply|calls|branch_computations)="
    r"(\{[^}]*\}|%?[\w.\-]+)"
)


def _shape_elems(dt: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt in _DTYPE_BYTES:
            total += _shape_elems(dt, dims) * _DTYPE_BYTES[dt]
    return total


def _shape_dims(text: str) -> list[int] | None:
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class _Instr:
    name: str
    shape_text: str
    opcode: str
    operands: list[str]
    rhs: str
    is_root: bool = False


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    coll_bytes: dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    coll_counts: dict[str, int] = dataclasses.field(
        default_factory=lambda: {k: 0 for k in _COLLECTIVES})
    unknown_trip_loops: int = 0

    @property
    def collective_bytes(self) -> float:
        return sum(self.coll_bytes.values())

    def add(self, other: "HloCost", mult: float = 1.0) -> None:
        self.flops += mult * other.flops
        self.bytes += mult * other.bytes
        self.transcendentals += mult * other.transcendentals
        for k in _COLLECTIVES:
            self.coll_bytes[k] += mult * other.coll_bytes[k]
            self.coll_counts[k] += int(mult * other.coll_counts[k])
        self.unknown_trip_loops += other.unknown_trip_loops


_TRANSCENDENTAL = {"exponential", "tanh", "log", "rsqrt", "sqrt", "power",
                   "logistic", "sine", "cosine", "exponential-minus-one"}


def _parse_instr(line: str) -> _Instr | None:
    s = line.strip()
    is_root = s.startswith("ROOT ")
    if is_root:
        s = s[5:]
    m = re.match(r"^%?([\w.\-]+)\s*=\s*(.*)$", s)
    if not m:
        return None
    name, rhs = m.group(1), m.group(2)
    # result shape: tuple '(...)' or single 'dtype[dims]{layout}'
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        shape_text = rhs[: i + 1]
        rest = rhs[i + 1:]
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return None
        shape_text = rhs[:sp]
        rest = rhs[sp:]
    om = re.match(r"\s*([a-z][\w\-]*)\s*\(", rest)
    if not om:
        return None
    opcode = om.group(1)
    # operand list: contents of the first paren group
    start = rest.find("(", om.start())
    depth = 0
    end = start
    for i in range(start, len(rest)):
        if rest[i] == "(":
            depth += 1
        elif rest[i] == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    operand_text = rest[start + 1:end]
    operands = re.findall(r"%([\w.\-]+)", operand_text)
    return _Instr(name, shape_text, opcode, operands, rhs, is_root)


def analyze_hlo(hlo_text: str) -> HloCost:
    # ------------------------------------------------ split into computations
    comps: dict[str, list[_Instr]] = {}
    shapes: dict[tuple[str, str], str] = {}
    current: str | None = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        # computation headers sit at column 0: `[ENTRY ]%name (params) -> shape {`
        if (line and not line[0].isspace() and line.endswith("{")
                and "->" in line):
            hm = re.match(r"^(?:ENTRY\s+)?%?([\w.\-$]+)\s*\(", line)
            if hm:
                current = hm.group(1)
                comps[current] = []
                # header params carry shapes
                header = line[line.find("("):line.rfind("->")]
                for pname, pshape in _PARAM_RE.findall(header):
                    shapes[(current, pname)] = pshape
                continue
        if line.strip() == "}" or line.startswith("}"):
            current = None
            continue
        if current is None:
            continue
        instr = _parse_instr(line)
        if instr:
            comps[current].append(instr)
            shapes[(current, instr.name)] = instr.shape_text

    def op_shape(cname: str, oname: str) -> str:
        return shapes.get((cname, oname), "")

    def _fusion_boundary_bytes(instr: _Instr, callee: str | None) -> float:
        """HBM traffic of one fused kernel: parameters consumed only through
        dynamic-slice count as the slice; the base of an in-place
        dynamic-update-slice root counts zero; a DUS root writes only the
        update.  Everything else is full operand/result size."""
        if callee is None or callee not in comps:
            return float(_shape_bytes(instr.shape_text))
        body = comps[callee]
        params = {i.name: i.shape_text for i in body if i.opcode == "parameter"}
        uses: dict[str, list[_Instr]] = {}
        root: _Instr | None = None
        for ins in body:
            if ins.is_root:
                root = ins
            if ins.opcode == "parameter":
                continue
            for o in ins.operands:
                if o in params:
                    uses.setdefault(o, []).append(ins)
        total = 0.0
        for pname, pshape in params.items():
            u = uses.get(pname, [])
            if u and all(x.opcode == "dynamic-slice" for x in u):
                total += sum(_shape_bytes(x.shape_text) for x in u)
            elif u and all(
                x.opcode == "dynamic-update-slice"
                and x.operands and x.operands[0] == pname
                for x in u
            ):
                total += 0.0       # aliased base of an in-place update
            else:
                total += _shape_bytes(pshape)
        if (root is not None and root.opcode == "dynamic-update-slice"
                and len(root.operands) > 1):
            upd = next((i.shape_text for i in body if i.name == root.operands[1]),
                       "")
            total += _shape_bytes(upd) or _shape_bytes(root.shape_text)
        else:
            total += _shape_bytes(instr.shape_text)
        return total

    memo: dict[str, HloCost] = {}
    called: set[str] = set()

    def callees_of(instr: _Instr) -> list[str]:
        out = []
        for grp in _CALL_ATTR_RE.findall(instr.rhs):
            grp = grp.strip("{}")
            for nm in grp.split(","):
                nm = nm.strip().lstrip("%")
                if nm:
                    out.append(nm)
        return out

    def trip_count(instr: _Instr, cname: str) -> float | None:
        m = _TRIP_RE.search(instr.rhs)
        if m:
            return float(m.group(1))
        # fallback: cond computation compares induction var against a constant
        cm = re.search(r"condition=%?([\w.\-]+)", instr.rhs)
        if cm and cm.group(1) in comps:
            text = "\n".join(i.rhs for i in comps[cm.group(1)])
            cc = re.search(r"constant\((\d+)\)", text)
            if cc and "direction=LT" in text:
                return float(cc.group(1))
        return None

    def comp_cost(cname: str, stack: tuple[str, ...] = ()) -> HloCost:
        if cname in memo:
            return memo[cname]
        cost = HloCost()
        if cname not in comps or cname in stack:
            return cost
        for instr in comps[cname]:
            op = instr.opcode
            base = op[:-6] if op.endswith("-start") else op
            # ---------------- collectives
            if base in _COLLECTIVES:
                if op.endswith("-done"):
                    continue
                nbytes = sum(_shape_bytes(op_shape(cname, o)) for o in instr.operands)
                cost.coll_bytes[base] += nbytes
                cost.coll_counts[base] += 1
                cost.bytes += nbytes + _shape_bytes(instr.shape_text)
                continue
            # ---------------- control flow / nesting
            if op == "while":
                mult = trip_count(instr, cname)
                if mult is None:
                    mult = 1.0
                    cost.unknown_trip_loops += 1
                for callee in callees_of(instr):
                    cost.add(comp_cost(callee, stack + (cname,)), mult)
                continue
            if op == "conditional":
                branches = [comp_cost(c, stack + (cname,)) for c in callees_of(instr)]
                if branches:
                    # charge the most expensive branch
                    best = max(branches, key=lambda c: c.flops + c.bytes)
                    cost.add(best)
                continue
            if op == "call":
                for callee in callees_of(instr):
                    cost.add(comp_cost(callee, stack + (cname,)))
                continue
            if op == "fusion":
                # one kernel: bytes at the boundary (slice-aware), flops inside
                callees = callees_of(instr)
                cost.bytes += _fusion_boundary_bytes(
                    instr, callees[0] if callees else None)
                for callee in callees:
                    inner = comp_cost(callee, stack + (cname,))
                    cost.flops += inner.flops
                    cost.transcendentals += inner.transcendentals
                continue
            if op == "dynamic-slice":
                # reads + writes only the slice, not the base operand
                cost.bytes += 2 * _shape_bytes(instr.shape_text)
                continue
            if op == "dynamic-update-slice":
                # in-place update: traffic = the update slice (read + write)
                upd = (op_shape(cname, instr.operands[1])
                       if len(instr.operands) > 1 else "")
                cost.bytes += 2 * _shape_bytes(upd)
                continue
            if op in ("gather", "scatter"):
                # index-driven: charge the moved elements, not the base table
                moved = _shape_bytes(instr.shape_text)
                if op == "scatter" and len(instr.operands) >= 3:
                    moved = _shape_bytes(op_shape(cname, instr.operands[2]))
                cost.bytes += 2 * moved
                continue
            # ---------------- dot
            if op == "dot":
                res_dims_bytes = _shape_bytes(instr.shape_text)
                res_elems = 0
                rd = _shape_dims(instr.shape_text)
                if rd is not None:
                    res_elems = 1
                    for d in rd:
                        res_elems *= d
                lhs_shape = op_shape(cname, instr.operands[0]) if instr.operands else ""
                ld = _shape_dims(lhs_shape) or []
                cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.rhs)
                contracted = 1
                if cm and ld:
                    for d in cm.group(1).split(","):
                        if d:
                            idx = int(d)
                            if idx < len(ld):
                                contracted *= ld[idx]
                cost.flops += 2.0 * res_elems * contracted
                cost.bytes += res_dims_bytes + sum(
                    _shape_bytes(op_shape(cname, o)) for o in instr.operands)
                continue
            if op == "convolution":
                # rare here; approximate: 2 × result × (window per output)
                res = _shape_dims(instr.shape_text) or []
                res_elems = 1
                for d in res:
                    res_elems *= d
                cost.flops += 2.0 * res_elems
                cost.bytes += _shape_bytes(instr.shape_text) + sum(
                    _shape_bytes(op_shape(cname, o)) for o in instr.operands)
                continue
            # ---------------- everything else
            if op in _FREE_OPS:
                continue
            nbytes = _shape_bytes(instr.shape_text) + sum(
                _shape_bytes(op_shape(cname, o)) for o in instr.operands)
            cost.bytes += nbytes
            if op in _TRANSCENDENTAL:
                rd = _shape_dims(instr.shape_text)
                if rd is not None:
                    n = 1
                    for d in rd:
                        n *= d
                    cost.transcendentals += n
            # count one flop per output element for arithmetic ops
            if op in ("add", "subtract", "multiply", "divide", "maximum",
                      "minimum", "select", "compare", "negate", "abs"):
                rd = _shape_dims(instr.shape_text)
                if rd is not None:
                    n = 1
                    for d in rd:
                        n *= d
                    cost.flops += n
        for instr in comps[cname]:
            for callee in callees_of(instr):
                called.add(callee)
        memo[cname] = cost
        return cost

    # resolve call graph: roots = computations never referenced
    for cname, instrs in comps.items():
        for instr in instrs:
            for callee in callees_of(instr):
                called.add(callee)
    roots = [c for c in comps if c not in called] or list(comps)
    total = HloCost()
    for r in roots:
        total.add(comp_cost(r))
    return total


# ------------------------------------------------- legacy collective report
@dataclasses.dataclass
class CollectiveReport:
    total_bytes: float
    by_kind: dict[str, float]
    counts: dict[str, int]
    unknown_trip_loops: int


def parse_hlo_collectives(hlo_text: str) -> CollectiveReport:
    c = analyze_hlo(hlo_text)
    return CollectiveReport(
        total_bytes=c.collective_bytes,
        by_kind=dict(c.coll_bytes),
        counts=dict(c.coll_counts),
        unknown_trip_loops=c.unknown_trip_loops,
    )


def collective_bytes(hlo_text: str) -> float:
    return analyze_hlo(hlo_text).collective_bytes
