"""Training launcher.

Runs real steps on the available devices (CPU smoke scale or a real pod —
same code path): builds the mesh that fits the device count (elastic), the
MAFIA-driven plan, the sharded train step, the deterministic data pipeline,
periodic + preemption-triggered checkpointing, and straggler tracking.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --smoke \
        --steps 50 --ckpt-dir /tmp/ckpt --ckpt-every 20

On restart with the same --ckpt-dir it resumes exactly (data cursor
included), even onto a different device count (reshard-on-restore).
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.registry import SHAPES, ShapeCell, get_arch
from repro.data.tokens import PipelineState, TokenPipeline
from repro.launch.steps import abstract_train_state
from repro.sharding.ctx import use_activation_sharding
from repro.sharding.planner import plan_for
from repro.train import checkpoint as ckpt
from repro.train.fault_tolerance import (
    PreemptionHandler,
    StragglerPolicy,
    elastic_mesh_shape,
)
from repro.train.optim import OptConfig
from repro.train.train_loop import init_state, make_train_step, state_specs

__all__ = ["main", "run_training"]


def build_mesh_for_devices() -> Mesh:
    devs = jax.devices()
    axes, used = elastic_mesh_shape(len(devs), prefer_model=min(16, len(devs)))
    shape = tuple(axes.values())
    return jax.make_mesh(shape, tuple(axes))


def run_training(
    arch: str,
    *,
    smoke: bool,
    steps: int,
    batch: int,
    seq_len: int,
    ckpt_dir: str | None,
    ckpt_every: int,
    microbatches: int,
    lr: float,
    log_every: int = 10,
) -> dict:
    spec = get_arch(arch)
    cfg = spec.smoke if smoke else spec.model
    mesh = build_mesh_for_devices()
    cell = ShapeCell("cli", "train", seq_len, batch)
    plan = plan_for(dataclasses.replace(spec, model=cfg), mesh, mode="train",
                    cell=cell)
    oc = OptConfig(lr=lr, warmup_steps=max(2, steps // 10), total_steps=steps)
    step_fn = make_train_step(cfg, oc, n_microbatches=microbatches)
    sspec = state_specs(plan)
    ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                is_leaf=lambda x: isinstance(x, P))
    state_sh = ns(sspec)
    batch_sh = {"tokens": NamedSharding(mesh, plan.batch_spec(batch))}
    jit_step = jax.jit(step_fn, in_shardings=(state_sh, batch_sh),
                       out_shardings=(state_sh, None), donate_argnums=(0,))

    pipe = TokenPipeline(vocab_size=cfg.vocab_size, batch=batch, seq_len=seq_len)
    pstate = PipelineState()
    start_step = 0
    if ckpt_dir and ckpt.latest_step(ckpt_dir) is not None:
        astate = abstract_train_state(cfg)
        astate = dataclasses.replace(astate, ef=None)
        state, meta = ckpt.restore(ckpt_dir, astate, shardings=state_sh)
        pstate = PipelineState.from_json(meta["pipeline"])
        start_step = int(meta["step"])
        print(f"resumed from step {start_step}")
    else:
        with mesh:
            state = init_state(cfg, jax.random.key(0))

    preempt = PreemptionHandler()
    straggler = StragglerPolicy()
    metrics_hist = []
    for i in range(start_step, steps):
        np_batch, pstate = pipe.batch_at(pstate)
        t0 = time.perf_counter()
        with mesh, use_activation_sharding(plan.act_specs):
            state, metrics = jit_step(
                state, {k: jnp.asarray(v) for k, v in np_batch.items()})
        dt = time.perf_counter() - t0
        if straggler.observe(dt):
            print(f"[straggler] step {i} took {dt:.2f}s "
                  f"(deadline {straggler.factor}×median); backup-dispatch hook")
        if (i + 1) % log_every == 0 or i == steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            metrics_hist.append({"step": i + 1, **m, "sec": dt})
            print(f"step {i+1:5d} loss={m['loss']:.4f} "
                  f"gnorm={m['grad_norm']:.3f} lr={m['lr']:.2e} ({dt:.2f}s)")
        want_save = ckpt_dir and ((i + 1) % ckpt_every == 0 or i == steps - 1)
        if want_save or (ckpt_dir and preempt.should_save):
            ckpt.save(ckpt_dir, i + 1, state,
                      metadata={"pipeline": pstate.to_json(), "step": i + 1,
                                "arch": arch})
            if preempt.should_save:
                print(f"[preemption] checkpoint saved at step {i+1}; exiting")
                break
    return {"final": metrics_hist[-1] if metrics_hist else {},
            "history": metrics_hist}


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    args = ap.parse_args()
    out = run_training(
        args.arch, smoke=args.smoke, steps=args.steps, batch=args.batch,
        seq_len=args.seq_len, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, microbatches=args.microbatches, lr=args.lr,
    )
    print("final:", out["final"])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
