import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × shape × mesh)
cell against placeholder devices and extract the §Roofline terms.

The two lines above MUST stay the first statements in this module — jax
locks the device count at first init, and the production meshes need 512
host platform devices.  (Smoke tests and benchmarks never import this
module, so they see the real single CPU device.)

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun \
        --arch all --shape all --mesh both --out experiments/dryrun

Each cell writes ``<out>/<arch>__<shape>__<mesh>.json`` with compile
timings, memory analysis, cost analysis, the collective schedule, and the
roofline terms; existing results are skipped unless ``--force``.
"""

import argparse       # noqa: E402
import json           # noqa: E402
import time           # noqa: E402
import traceback      # noqa: E402

import jax            # noqa: E402

from repro.configs.registry import ARCH_IDS, SHAPES, get_arch            # noqa: E402
from repro.launch.hlo_analysis import analyze_hlo, xla_cost_analysis     # noqa: E402
from repro.launch.mesh import make_production_mesh                       # noqa: E402
from repro.launch.roofline import summarize                              # noqa: E402
from repro.launch.steps import build_cell                                # noqa: E402

__all__ = ["run_cell", "main", "OPT_OVERRIDES"]

# Beyond-paper optimized-variant config overrides per arch (EXPERIMENTS.md
# §Perf).  The MoE one-scatter dispatch and grad-accumulator sharding are in
# the code itself; these are the per-arch knobs that change parameter
# layouts and therefore stay opt-in.
OPT_OVERRIDES: dict[str, dict] = {
    "musicgen-medium": {"head_pad_multiple": 16},   # 24 heads → 32, TP-able
}


def _args_bytes_per_device(args, shardings) -> float:
    total = 0
    for leaf, ns in zip(jax.tree.leaves(args), jax.tree.leaves(
            shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding))):
        shard = ns.shard_shape(leaf.shape) if ns is not None else leaf.shape
        n = 1
        for d in shard:
            n *= d
        total += n * leaf.dtype.itemsize
    return float(total)


def run_cell(
    arch_id: str,
    shape_name: str,
    *,
    multi_pod: bool,
    pod_reduce: str = "fp32",
    keep_hlo: bool = False,
    allow_uneven: bool = False,
    cfg_overrides: dict | None = None,
) -> dict:
    spec = get_arch(arch_id)
    cell = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec: dict = {
        "arch": arch_id, "shape": shape_name, "mesh": mesh_name,
        "pod_reduce": pod_reduce, "status": "ok",
    }
    if shape_name in spec.skip_cells:
        rec["status"] = "skipped"
        rec["reason"] = spec.skip_cells[shape_name]
        return rec
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        n_chips = mesh.devices.size
        t0 = time.perf_counter()
        prog = build_cell(spec, cell, mesh, pod_reduce=pod_reduce,
                          allow_uneven=allow_uneven, cfg_overrides=cfg_overrides)
        t1 = time.perf_counter()
        lowered = prog.lower(mesh)
        t2 = time.perf_counter()
        compiled = lowered.compile()
        t3 = time.perf_counter()
        rec["plan_s"] = t1 - t0
        rec["lower_s"] = t2 - t1
        rec["compile_s"] = t3 - t2
        rec["meta"] = prog.meta

        # ---- memory: argument footprint per device (+ backend analysis)
        rec["arg_bytes_per_device"] = _args_bytes_per_device(
            prog.args, prog.in_shardings)
        try:
            ma = compiled.memory_analysis()
            if ma is not None:
                for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                             "temp_size_in_bytes", "generated_code_size_in_bytes",
                             "alias_size_in_bytes"):
                    v = getattr(ma, attr, None)
                    if v is not None:
                        rec[f"mem_{attr}"] = float(v)
        except Exception as e:  # pragma: no cover - backend-specific
            rec["memory_analysis_error"] = str(e)

        # ---- trip-count-aware cost + collectives → roofline
        xla_cost = xla_cost_analysis(compiled)
        rec["xla_cost_flops"] = float(xla_cost.get("flops", 0.0))
        rec["xla_cost_bytes"] = float(xla_cost.get("bytes accessed", 0.0))
        hlo = compiled.as_text()
        rec["hlo_lines"] = hlo.count("\n")
        cost = analyze_hlo(hlo)
        rec["collectives"] = {
            "total_bytes": cost.collective_bytes,
            "by_kind": cost.coll_bytes,
            "counts": cost.coll_counts,
            "unknown_trip_loops": cost.unknown_trip_loops,
        }
        rec["roofline"] = summarize(prog.cfg, cell, cost, n_chips)
        if keep_hlo:
            rec["hlo"] = hlo
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    return rec


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all",
                    help="arch id, comma list, or 'all'")
    ap.add_argument("--shape", default="all",
                    help="shape cell, comma list, or 'all'")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--pod-reduce", default="fp32", choices=["fp32", "int8_ef"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--opt", action="store_true",
                    help="apply the beyond-paper per-arch overrides")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    if args.list:
        for a in archs:
            spec = get_arch(a)
            for s in shapes:
                state = "SKIP" if s in spec.skip_cells else "run"
                print(f"{a:20s} {s:12s} {state}")
        return 0

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for a in archs:
        for s in shapes:
            for mp in meshes:
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                suffix = "" if args.pod_reduce == "fp32" else f"__{args.pod_reduce}"
                path = os.path.join(args.out, f"{a}__{s}__{mesh_name}{suffix}.json")
                if os.path.exists(path) and not args.force:
                    print(f"[cached] {path}")
                    continue
                t0 = time.perf_counter()
                rec = run_cell(a, s, multi_pod=mp, pod_reduce=args.pod_reduce,
                               cfg_overrides=OPT_OVERRIDES.get(a) if args.opt
                               else None)
                dt = time.perf_counter() - t0
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1, default=float)
                tag = rec["status"].upper()
                extra = ""
                if rec["status"] == "ok":
                    r = rec["roofline"]
                    extra = (f"dom={r['dominant']} comp={r['compute_s']:.4f}s "
                             f"mem={r['memory_s']:.4f}s coll={r['collective_s']:.4f}s")
                elif rec["status"] == "error":
                    failures += 1
                    extra = rec["error"][:160]
                print(f"[{tag}] {a} {s} {mesh_name} ({dt:.1f}s) {extra}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
