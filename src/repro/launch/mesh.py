"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis extends
data parallelism (gradient reduce over (pod, data)), scaling to 1000+ nodes
by growing ``pod`` — weights are never sharded across pods, so cross-pod
traffic is gradients only (optionally int8-EF compressed,
:mod:`repro.train.compression`).

``make_production_mesh`` is a function (not a module constant) so importing
this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
initialization, and smoke tests see the real single CPU device.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "mesh_axes", "abstract_mesh"]


def abstract_mesh(shape: tuple[int, ...], axis_names: tuple[str, ...]):
    """Version-tolerant ``jax.sharding.AbstractMesh`` constructor.

    JAX has flipped this signature between releases: older versions take
    ``AbstractMesh(shape_tuple)`` with ``shape_tuple = ((name, size), ...)``,
    newer ones take ``AbstractMesh(axis_sizes, axis_names)``.  Planner code
    only ever needs (sizes, names), so accept that and adapt.
    """
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(tuple(zip(axis_names, shape)))
    except (TypeError, ValueError):
        return AbstractMesh(tuple(shape), tuple(axis_names))


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_axes(mesh: jax.sharding.Mesh) -> dict[str, int]:
    return dict(mesh.shape)
