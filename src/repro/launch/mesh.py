"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis extends
data parallelism (gradient reduce over (pod, data)), scaling to 1000+ nodes
by growing ``pod`` — weights are never sharded across pods, so cross-pod
traffic is gradients only (optionally int8-EF compressed,
:mod:`repro.train.compression`).

``make_production_mesh`` is a function (not a module constant) so importing
this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
initialization, and smoke tests see the real single CPU device.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "mesh_axes"]


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_axes(mesh: jax.sharding.Mesh) -> dict[str, int]:
    return dict(mesh.shape)
