"""Serving launcher: batched generation with the slot engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
        --requests 6 --max-new 12
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.registry import get_arch
from repro.models.transformer import init_params
from repro.serve.engine import ServeEngine

__all__ = ["main"]


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    spec = get_arch(args.arch)
    cfg = spec.smoke if args.smoke else spec.model
    params = init_params(cfg, jax.random.key(args.seed))
    engine = ServeEngine(cfg, params, max_batch=args.max_batch,
                         max_len=args.max_len)
    rng = np.random.default_rng(args.seed)
    t0 = time.perf_counter()
    for i in range(args.requests):
        plen = int(rng.integers(4, 24))
        engine.submit(list(rng.integers(1, cfg.vocab_size, size=plen)),
                      max_new_tokens=args.max_new)
    done = engine.run_to_completion()
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.tokens) for r in done)
    for r in done:
        print(f"req {r.rid}: {len(r.prompt)} prompt → {r.tokens}")
    print(f"{len(done)} requests, {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens/dt:.1f} tok/s incl. compiles)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
