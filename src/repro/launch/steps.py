"""Build the jit-able step function + shardings + abstract inputs for one
(architecture × shape cell × mesh) — shared by the dry-run, the trainer and
the server.

Each builder returns a :class:`CellProgram`:
    fn            — pure step function
    args          — abstract (ShapeDtypeStruct) positional args
    in_shardings  — NamedSharding tree congruent with ``args``
    out_shardings — NamedSharding tree (or None leaves = compiler choice)
    donate        — arg indices donated (state / caches)
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.registry import ArchSpec, ShapeCell
from repro.models.transformer import (
    ModelConfig,
    abstract_params,
    forward_decode,
    forward_full,
    init_cache,
)
from repro.sharding.ctx import use_activation_sharding
from repro.sharding.planner import Plan, plan_for
from repro.train.optim import OptConfig
from repro.train.train_loop import TrainState, make_train_step, state_specs

__all__ = ["CellProgram", "build_cell", "abstract_train_state"]


@dataclasses.dataclass
class CellProgram:
    arch_id: str
    cell: ShapeCell
    kind: str
    fn: Callable
    args: tuple
    in_shardings: tuple
    out_shardings: Any
    donate: tuple[int, ...]
    plan: Plan
    cfg: ModelConfig
    meta: dict[str, Any]

    def lower(self, mesh: jax.sharding.Mesh):
        jitted = jax.jit(
            self.fn,
            in_shardings=self.in_shardings,
            out_shardings=self.out_shardings,
            donate_argnums=self.donate,
        )
        with mesh:
            with use_activation_sharding(self.plan.act_specs):
                return jitted.lower(*self.args)


def abstract_train_state(cfg: ModelConfig) -> TrainState:
    from repro.train.train_loop import init_state

    return jax.eval_shape(lambda: init_state(cfg, jax.random.key(0)))


def _ns(mesh, spec_tree):
    return jax.tree.map(
        lambda s: None if s is None else NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P) or x is None,
    )


def _batch_abstract(cfg: ModelConfig, cell: ShapeCell, batch: int) -> dict:
    if cfg.modality == "vision_prefix":
        s_text = cell.seq_len - cfg.vision_prefix_len
        return {
            "tokens": jax.ShapeDtypeStruct((batch, s_text), jnp.int32),
            "prefix": jax.ShapeDtypeStruct(
                (batch, cfg.vision_prefix_len, cfg.d_model), cfg.adt),
        }
    return {"tokens": jax.ShapeDtypeStruct((batch, cell.seq_len), jnp.int32)}


def _batch_pspec(plan: Plan, batch: int, abstract: dict) -> dict:
    dp = plan.dp_axes if plan.dp_size and batch % plan.dp_size == 0 else None
    return {k: P(dp, *([None] * (v.ndim - 1))) for k, v in abstract.items()}


def build_cell(
    spec: ArchSpec,
    cell: ShapeCell,
    mesh: jax.sharding.Mesh,
    *,
    pod_reduce: str = "fp32",
    microbatch_override: int | None = None,
    allow_uneven: bool = False,
    cfg_overrides: dict | None = None,
) -> CellProgram:
    cfg = spec.cell_config(cell)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
        spec = dataclasses.replace(spec, model=cfg)
    plan = plan_for(
        spec, mesh, mode=cell.kind, cell=cell,
        cache_batch=cell.global_batch if cell.kind == "decode" else None,
        cache_len=cell.seq_len if cell.kind == "decode" else None,
        allow_uneven=allow_uneven,
        replicate_embed=pod_reduce == "int8_ef",
    )
    meta: dict[str, Any] = {"notes": list(plan.notes)}

    if cell.kind == "train":
        dp = max(1, plan.dp_size)
        n_micro = microbatch_override or spec.train_microbatches
        n_micro = max(1, min(n_micro, cell.global_batch // dp))
        meta["n_microbatches"] = n_micro
        # microbatch reshape hint: (n_micro, mb, S) with mb sharded over dp
        plan.act_specs.setdefault("microbatches", P(None, plan.dp_axes, None))
        step = make_train_step(
            cfg, OptConfig(), n_microbatches=n_micro,
            pod_reduce=pod_reduce, mesh=mesh,
            grad_specs=plan.param_specs,
        )
        astate = abstract_train_state(cfg)
        if pod_reduce != "int8_ef":
            astate = dataclasses.replace(astate, ef=None)
        else:
            from repro.train.compression import ef_init

            astate = dataclasses.replace(
                astate, ef=jax.eval_shape(lambda p: ef_init(p), astate.params))
        abatch = _batch_abstract(cfg, cell, cell.global_batch)
        sspec = state_specs(plan, ef=pod_reduce == "int8_ef")
        in_sh = (_ns(mesh, sspec), _ns(mesh, _batch_pspec(plan, cell.global_batch, abatch)))
        out_sh = (_ns(mesh, sspec),
                  _ns(mesh, {"loss": P(), "grad_norm": P(), "lr": P()}))
        return CellProgram(
            arch_id=spec.arch_id, cell=cell, kind="train", fn=step,
            args=(astate, abatch), in_shardings=in_sh, out_shardings=out_sh,
            donate=(0,), plan=plan, cfg=cfg, meta=meta,
        )

    aparams = abstract_params(cfg)
    p_ns = _ns(mesh, plan.param_specs)

    if cell.kind == "prefill":
        def prefill_step(params, batch):
            logits, caches, _ = forward_full(
                params, cfg, batch["tokens"],
                prefix_embeds=batch.get("prefix"), return_cache=True,
            )
            return logits, caches

        abatch = _batch_abstract(cfg, cell, cell.global_batch)
        in_sh = (p_ns, _ns(mesh, _batch_pspec(plan, cell.global_batch, abatch)))
        cache_plan = plan_for(spec, mesh, mode="prefill", cell=cell,
                              cache_batch=cell.global_batch, cache_len=cell.seq_len)
        out_sh = (None, _ns(mesh, cache_plan.cache_specs))
        return CellProgram(
            arch_id=spec.arch_id, cell=cell, kind="prefill", fn=prefill_step,
            args=(aparams, abatch), in_shardings=in_sh, out_shardings=out_sh,
            donate=(), plan=plan, cfg=cfg, meta=meta,
        )

    # ---- decode: 1 new token per sequence against a seq_len cache
    B = cell.global_batch

    def serve_step(params, token, caches, pos):
        return forward_decode(params, cfg, token, caches, pos)

    acache = init_cache(cfg, B, cell.seq_len, abstract=True)
    atoken = jax.ShapeDtypeStruct((B,), jnp.int32)
    apos = jax.ShapeDtypeStruct((B,), jnp.int32)
    dp = plan.dp_axes if plan.dp_size and B % plan.dp_size == 0 else None
    tok_ns = NamedSharding(mesh, P(dp))
    in_sh = (p_ns, tok_ns, _ns(mesh, plan.cache_specs), tok_ns)
    Vp = cfg.padded_vocab
    logits_spec = plan.act_specs.get("logits", P(dp, None))
    lg = P(dp, logits_spec[-1] if len(logits_spec) else None)
    out_sh = (NamedSharding(mesh, lg), _ns(mesh, plan.cache_specs))
    return CellProgram(
        arch_id=spec.arch_id, cell=cell, kind="decode", fn=serve_step,
        args=(aparams, atoken, acache, apos), in_shardings=in_sh,
        out_shardings=out_sh, donate=(2,), plan=plan, cfg=cfg, meta=meta,
    )
