"""Serve the paper's classical models as a batched inference service,
including the fused linear-pipeline Pallas path (§IV-G on TPU).

    PYTHONPATH=src python examples/serve_classical.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import MafiaCompiler
from repro.data.datasets import get_spec, make_dataset
from repro.models import bonsai


def main() -> None:
    spec = get_spec("mnist-b")
    Xtr, ytr, Xte, yte = make_dataset(spec, n_train=512, n_test=512)
    cfg = bonsai.from_spec(spec)
    params = bonsai.train(cfg, Xtr, ytr, steps=150)

    # compile twice: plain vs fused-pipeline execution
    progs = {
        "plain": MafiaCompiler(use_pallas=False).compile(
            bonsai.build_dfg(params, cfg)),
        "fused-pallas": MafiaCompiler(use_pallas=True).compile(
            bonsai.build_dfg(params, cfg)),
    }
    x0 = Xte[0]
    ref = None
    for name, prog in progs.items():
        out = prog(x=x0)
        if ref is None:
            ref = out["ClassSum"]
        np.testing.assert_allclose(out["ClassSum"], ref, rtol=1e-4, atol=1e-4)
        # simple request loop: one sample at a time (the paper's setting)
        prog(x=x0)  # warm
        t0 = time.perf_counter()
        for i in range(64):
            out = prog(x=Xte[i % len(Xte)])
        jax.block_until_ready(out["ClassSum"])
        us = (time.perf_counter() - t0) / 64 * 1e6
        print(f"{name:13s}: {us:8.1f} us/request (host wall-clock), "
              f"simulated FPGA latency {prog.latency_us:.1f} us")

    # batched JAX path (the TPU-adaptation: PF reappears as batch/grid
    # parallelism — see DESIGN.md §2)
    pred = jnp.argmax(bonsai.predict(
        {k: jnp.asarray(v) for k, v in params.items()}, cfg,
        jnp.asarray(Xte)), -1)
    acc = float((np.asarray(pred) == yte).mean())
    print(f"batched accuracy over {len(yte)} requests: {acc:.3f}")


if __name__ == "__main__":
    main()
