"""Serve the paper's classical models as a batched inference service.

Four tiers, slowest to fastest:

1. the paper's own setting — one request at a time through the compiled
   program (optionally via the fused linear-pipeline Pallas path, §IV-G),
2. the batched serving engine (:mod:`repro.serve.classical_engine`):
   enqueue → pad to power-of-two bucket → one batched forward per bucket,
2c. the async continuous-batching tier (:mod:`repro.serve.async_engine`):
   staggered arrivals under an SLO deadline, partial buckets refilled and
   flushed just in time — the production framing of the same forward,
3. the raw batched JAX reference (no request framing at all) as the ceiling.

    PYTHONPATH=src python examples/serve_classical.py
"""

import asyncio
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import MafiaCompiler
from repro.data.datasets import get_spec, make_dataset
from repro.models import bonsai
from repro.serve.classical_engine import ClassicalServeEngine

N_REQUESTS = 256


def main() -> None:
    spec = get_spec("mnist-b")
    Xtr, ytr, Xte, yte = make_dataset(spec, n_train=512, n_test=N_REQUESTS)
    cfg = bonsai.from_spec(spec)
    params = bonsai.train(cfg, Xtr, ytr, steps=150)

    # ---- tier 1: per-sample request loop, plain vs fused-pipeline Pallas
    progs = {
        "plain": MafiaCompiler(use_pallas=False).compile(
            bonsai.build_dfg(params, cfg)),
        "fused-pallas": MafiaCompiler(use_pallas=True).compile(
            bonsai.build_dfg(params, cfg)),
    }
    x0 = Xte[0]
    ref = None
    for name, prog in progs.items():
        out = prog(x=x0)
        if ref is None:
            ref = out["ClassSum"]
        np.testing.assert_allclose(out["ClassSum"], ref, rtol=1e-4, atol=1e-4)
        prog(x=x0)  # warm
        t0 = time.perf_counter()
        for i in range(64):
            out = prog(x=Xte[i % len(Xte)])
        jax.block_until_ready(out["ClassSum"])
        us = (time.perf_counter() - t0) / 64 * 1e6
        print(f"per-sample {name:13s}: {us:8.1f} us/request, "
              f"simulated FPGA latency {prog.latency_us:.1f} us")

    # ---- tier 2: the batched serving engine over the same compiled program
    for mode in ("map", "vmap"):
        eng = ClassicalServeEngine(progs["plain"], max_batch=64, mode=mode)
        for x in Xte[:64]:                   # warm the bucket's jit entry
            eng.submit(x)
        eng.run_to_completion()
        eng.reset_stats()
        for x in Xte:
            eng.submit(x)
        done = eng.run_to_completion()
        acc = float(np.mean([r.pred == y for r, y in zip(done, yte)]))
        print(f"engine mode={mode:4s}: {1e6 / eng.throughput():8.1f} us/request "
              f"({eng.throughput():,.0f} req/s), buckets {eng.batched.stats}, "
              f"accuracy {acc:.3f}")

    # ---- tier 2b: the engine on the int8 fixed-point lane — the arithmetic
    # the paper's SeeDot-lineage programs actually run, calibrated from the
    # training split (power-of-two scales, int32 accumulation)
    prog_q = MafiaCompiler(precision="int8").compile(
        bonsai.build_dfg(params, cfg), calib=Xtr)
    eng = ClassicalServeEngine(prog_q, max_batch=64, mode="vmap")
    for x in Xte[:64]:
        eng.submit(x)
    eng.run_to_completion()
    eng.reset_stats()
    for x in Xte:
        eng.submit(x)
    done = eng.run_to_completion()
    acc = float(np.mean([r.pred == y for r, y in zip(done, yte)]))
    print(f"engine int8     : {1e6 / eng.throughput():8.1f} us/request "
          f"({eng.throughput():,.0f} req/s), accuracy {acc:.3f}")

    # ---- tier 2c: async continuous batching — requests arrive staggered,
    # each under an SLO; partial buckets flush just in time, so occupancy
    # stays > 1 without ever waiting a full bucket's worth of arrivals
    async def serve_async() -> None:
        from repro.serve.async_engine import AsyncServeEngine

        eng = AsyncServeEngine()
        eng.register_model("bonsai", progs["plain"], slo_ms=50.0,
                           max_batch=64)
        n = 1
        while n <= 64:                      # warm each bucket's jit entry
            for x in Xte[:n]:
                eng.submit("bonsai", x)
            eng.drain()
            n *= 2
        eng.metrics.reset()
        eng._models["bonsai"].metrics.reset()
        runner = asyncio.create_task(eng.run())
        reqs = []
        for x in Xte:
            reqs.append(await eng.submit_async("bonsai", x))
            await asyncio.sleep(0.0002)     # staggered arrivals
        done = await asyncio.gather(*(eng.result(r) for r in reqs))
        eng.stop()
        await runner
        acc = float(np.mean([r.pred == y for r, y in zip(done, yte)]))
        s = eng.stats()
        print(f"async slo=50ms  : p50 {s['p50_ms']:.1f} ms, "
              f"p99 {s['p99_ms']:.1f} ms, {s['rps']:,.0f} req/s arrival-"
              f"bound, occupancy {s['batch_occupancy']:.1f}, "
              f"slo misses {s['slo_misses']}, accuracy {acc:.3f}")

    asyncio.run(serve_async())

    # ---- tier 3: raw batched JAX reference (the ceiling; no request framing)
    pj = {k: jnp.asarray(v) for k, v in params.items()}
    fn = jax.jit(lambda X: jnp.argmax(bonsai.predict(pj, cfg, X), -1))
    jax.block_until_ready(fn(jnp.asarray(Xte)))
    t0 = time.perf_counter()
    pred = fn(jnp.asarray(Xte))
    jax.block_until_ready(pred)
    us = (time.perf_counter() - t0) / len(Xte) * 1e6
    acc = float((np.asarray(pred) == yte).mean())
    print(f"raw batched ref  : {us:8.1f} us/request, accuracy {acc:.3f}")


if __name__ == "__main__":
    main()
