"""Quickstart: compile a classical-ML model with MAFIA and run it.

    PYTHONPATH=src python examples/quickstart.py

Covers the paper's core loop end to end: train ProtoNN on a dataset,
extract its matrix DFG, let the Best-PF estimator assign parallelism
factors, inspect the schedule, and execute the compiled program — then the
same model through the TensorFlow-subset frontend.
"""

import numpy as np

import repro.frontends.tf_subset as tf
from repro.core import MafiaCompiler
from repro.data.datasets import get_spec, make_dataset
from repro.models import protonn


def main() -> None:
    # 1. data + model (ProtoNN = compressed kNN, one of the paper's two)
    spec = get_spec("usps-b")
    Xtr, ytr, Xte, yte = make_dataset(spec, n_train=512, n_test=128)
    cfg = protonn.from_spec(spec)
    params = protonn.train(cfg, Xtr, ytr, steps=150)
    print(f"trained ProtoNN/{spec.name}: "
          f"accuracy={protonn.accuracy(params, cfg, Xte, yte):.3f}")

    # 2. matrix DFG → MAFIA compile (greedy Best-PF, dataflow schedule)
    dfg = protonn.build_dfg(params, cfg)
    prog = MafiaCompiler(backend="fpga", strategy="greedy",
                         metric="latency_per_lut").compile(dfg)
    print(f"nodes={len(dfg.nodes)}  latency={prog.latency_us:.1f}us "
          f"LUT={prog.lut_true:.0f}/{prog.budget.luts} "
          f"DSP={prog.dsp_true:.0f}/{prog.budget.dsps}")
    print("per-node PF:", prog.assignment)
    print("pipelined linear clusters:", prog.schedule.pipelined_clusters)

    # 3. execute the compiled program (JAX) — same math as the reference
    out = prog(x=Xte[0])
    print(f"compiled prediction={int(out['Pred'][0])}  label={int(yte[0])}")

    # 4. the TF-subset frontend: trace python → SeeDot → DFG
    def program(x):
        h = tf.sparse_matmul_vec(params["W"], x)
        d2 = tf.squared_distance(h, params["B"])
        sim = tf.exp(tf.scale(d2, -float(params["gamma"]) ** 2))
        return tf.matmul_vec(params["Zs"], sim)

    g2 = tf.trace(program, inputs={"x": (spec.n_features,)})
    prog2 = MafiaCompiler().compile(g2)
    out2 = list(prog2(x=Xte[0]).values())[0]
    np.testing.assert_allclose(out2, out["ScoreSum"], rtol=1e-4)
    print("tf-subset trace matches the hand-built DFG ✓")


if __name__ == "__main__":
    main()
