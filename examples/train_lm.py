"""End-to-end driver: train a ~small LM for a few hundred steps on CPU with
the full production substrate — MAFIA-planned sharding, microbatch
accumulation, checkpoints, preemption handling — then generate from it with
the serving engine.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--arch qwen2.5-3b]

(Uses the arch's reduced smoke config so it runs on one CPU in minutes; on a
pod the same code path runs the full config — see repro.launch.train.)
"""

import argparse
import tempfile

import jax
import numpy as np

from repro.launch.train import run_training
from repro.configs import get_arch
from repro.models.transformer import init_params
from repro.serve.engine import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as ckpt_dir:
        out = run_training(
            args.arch, smoke=True, steps=args.steps, batch=16, seq_len=64,
            ckpt_dir=ckpt_dir, ckpt_every=max(10, args.steps // 4),
            microbatches=2, lr=5e-3,
        )
        hist = out["history"]
        print(f"\nloss: {hist[0]['loss']:.3f} → {hist[-1]['loss']:.3f} "
              f"over {args.steps} steps")
        assert hist[-1]["loss"] < hist[0]["loss"], "training must learn"

    # generate from the trained weights' config (fresh engine, same arch)
    cfg = get_arch(args.arch).smoke
    params = init_params(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params, max_batch=4, max_len=96)
    rng = np.random.default_rng(0)
    for _ in range(3):
        eng.submit(list(rng.integers(1, cfg.vocab_size, size=8)),
                   max_new_tokens=8)
    for r in eng.run_to_completion():
        print(f"request {r.rid}: generated {r.tokens}")


if __name__ == "__main__":
    main()
